# Developer entry points.  Everything here is plain python underneath;
# the Makefile just pins the invocations CI uses so local runs match
# the gate exactly.
PY ?= python
LINT_PATHS = src tests tools benchmarks examples
BASELINE = .repro-lint-baseline.json

.PHONY: lint lint-baseline lint-fixtures test test-mesh links

# Trace-safety & determinism lint (docs/static-analysis.md).
# stdlib-only — needs no installs beyond the repo checkout.
lint:
	$(PY) -m tools.repro_lint $(LINT_PATHS) --baseline $(BASELINE)

# Regenerate the baseline (shrink-only: tests assert its total is 0).
lint-baseline:
	$(PY) -m tools.repro_lint $(LINT_PATHS) --write-baseline $(BASELINE)

# Self-test of the gate: the bad-fixture corpus must FAIL the linter.
lint-fixtures:
	@if $(PY) -m tools.repro_lint tests/fixtures/lint --include-fixtures; \
	then echo "bad-fixture corpus must fail the linter"; exit 1; \
	else echo "ok: fixture corpus fires"; fi

# Tier-1 suite under the same forced-device count as CI.
test:
	XLA_FLAGS=--xla_force_host_platform_device_count=2 \
	PYTHONPATH=src $(PY) -m pytest -x -q

# The 2-D mesh / streaming-quantile leg (needs a factorable count).
test-mesh:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_mesh2d.py \
	  tests/test_streaming_quantiles.py

links:
	$(PY) tools/check_links.py
