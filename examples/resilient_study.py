"""Resilient sweep study: checkpoint/resume + fault quarantine demo
(docs/reliability.md).

Runs a design × scenario × seed grid through `resilient_sweep` with
per-chunk checkpointing, kills it after the second chunk commits
(injected crash — stand-in for preemption / OOM-kill), resumes from the
same checkpoint directory, and verifies the resumed result is bitwise
identical to an uninterrupted run.  A second pass injects one poisoned
configuration and shows the quarantine report: only that row is lost
(NaN sentinels), every other row is bitwise unchanged.

    PYTHONPATH=src python examples/resilient_study.py [--scale 0.01]
"""
import argparse
import shutil
import tempfile
import time

import numpy as np

from repro.core import hierarchy, projections as proj
from repro.core.arrivals import EnvelopeSpec
from repro.core.resilience import (FaultPlan, InjectedCrash,
                                   resilient_sweep)
from repro.core.sweep import SweepAxes, sweep
from repro.runtime.fault import Backoff


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01)
    ap.add_argument("--chunk", type=int, default=4)
    args = ap.parse_args()

    names = ("4N/3", "3+1")
    combos = [(n, s, sd) for n in names for s in (proj.MED, proj.HIGH)
              for sd in (0, 1, 2)]
    axes = SweepAxes.zip(
        designs=[hierarchy.get_design(n) for n, _, _ in combos],
        envs=[EnvelopeSpec(demand_scale=args.scale, gpu_scenario=s,
                           end_year=2028) for _, s, _ in combos],
        seeds=[sd for *_, sd in combos])
    print(f"{len(axes)} configurations, chunk_size={args.chunk}")

    ref = sweep(axes)

    # ---- kill-and-resume -------------------------------------------------
    ckdir = tempfile.mkdtemp(prefix="resilient_study_")
    try:
        try:
            resilient_sweep(axes, chunk_size=args.chunk,
                            checkpoint_dir=ckdir,
                            fault_plan=FaultPlan(crash_after=1))
        except InjectedCrash as e:
            print(f"crashed: {e}")
        t0 = time.time()
        res = resilient_sweep(axes, chunk_size=args.chunk,
                              checkpoint_dir=ckdir)
        r = res.report
        bitwise = np.array_equal(res.final_deployed_mw,
                                 ref.final_deployed_mw)
        print(f"resumed in {time.time() - t0:.1f}s: "
              f"{r.chunks_resumed} chunks loaded, "
              f"{r.chunks_computed} recomputed, "
              f"bitwise_equal={bitwise}")
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    # ---- quarantine ------------------------------------------------------
    res = resilient_sweep(axes, chunk_size=args.chunk,
                          fault_plan=FaultPlan(poison=(5,)),
                          backoff=Backoff(base_s=0.0, max_retries=1))
    r = res.report
    keep = [i for i in range(len(axes)) if i not in r.quarantined_indices()]
    print(f"quarantined={list(r.quarantined_indices())} "
          f"reason={r.quarantined[0].reason} retries={r.retries}")
    print(f"other rows bitwise_equal="
          f"{np.array_equal(res.final_deployed_mw[keep], ref.final_deployed_mw[keep])}; "
          f"quarantined row is NaN="
          f"{bool(np.isnan(res.final_deployed_mw[5]))}")


if __name__ == "__main__":
    main()
