"""Pod payoff study (paper §6.5, Figs. 17–18): when do larger GPU pods'
serving gains survive their deployability cost?

    PYTHONPATH=src python examples/pod_payoff_study.py
"""
from repro.core import hierarchy, payoff, throughput as tp
from repro.core.arrivals import EnvelopeSpec


def main():
    env = EnvelopeSpec(demand_scale=0.03, gpu_scenario="high",
                       pod_scale_arch=True)
    models = [tp.MODELS[n] for n in
              ("MoE-0.6T", "MoE-19T", "MoE-132T", "MoE-401T")]
    for dname in ("10N/8", "8+2"):
        print(f"== {dname} ==")
        pts = payoff.pod_payoff_study(hierarchy.get_design(dname), models,
                                      pod_sizes=(1, 3, 5, 7), env=env)
        print(f"{'model':10s} {'pod':>4s} {'dTPS/W':>8s} {'dCost':>8s} "
              f"{'payoff':>8s}")
        for p in pts:
            if p.pod_racks == 1:
                continue
            print(f"{p.model:10s} {p.pod_racks:4d} {p.d_tps_per_watt:+7.1%} "
                  f"{p.d_cost:+7.1%} {p.payoff:+7.1%}")


if __name__ == "__main__":
    main()
