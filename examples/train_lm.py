"""End-to-end training driver: trains a ~100M-parameter Qwen3-family model
for a few hundred steps with the full production stack — shard-aware data
pipeline, AdamW, async checkpointing, straggler supervision.

Default runs a ~10M config for 100 steps (~2 min on this 1-core CPU
container); --size 100m trains the ~100M config (same code path, longer).

    PYTHONPATH=src python examples/train_lm.py [--size 100m --steps 300]
"""
import argparse
from dataclasses import replace

from repro.configs.base import get_smoke_config
from repro.launch import train as train_launch


SIZES = {
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                d_ff=1024, vocab=8192, head_dim=64),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab=32768, head_dim=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", default="10m", choices=list(SIZES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = replace(get_smoke_config("qwen3-1.7b"), **SIZES[args.size],
                  remat="none")
    import repro.launch.train as T
    import jax, jax.numpy as jnp
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.data.pipeline import PipelineConfig, TokenPipeline
    from repro.models.api import build_model
    from repro.optim import adamw
    from repro.runtime.fault import Supervisor
    from repro.train.step import make_train_step

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    print(f"params={model.n_params():,}")
    step_fn = jax.jit(make_train_step(model, adamw.AdamWConfig(lr=1e-3)),
                      donate_argnums=(0, 1))
    pipe = TokenPipeline(PipelineConfig(args.batch, args.seq, cfg.vocab))
    ckpt = Checkpointer("/tmp/repro_train_lm", keep=2)

    def one(state, step):
        p, o = state
        p, o, m = step_fn(p, o, {"tokens": jnp.asarray(pipe._batch_at(step))})
        if step % 20 == 0:
            print(f"  step {step}: loss={float(m['loss']):.3f} "
                  f"gnorm={float(m['grad_norm']):.2f}")
        return (p, o), m

    sup = Supervisor(one, lambda s, st: ckpt.save(s, st),
                     lambda: ckpt.restore((params, opt_state)),
                     checkpoint_every=50)
    state, step, hist, _ = sup.run((params, opt_state), 0, args.steps)
    ckpt.wait()
    print(f"done: steps={step} "
          f"loss {float(hist[0]['loss']):.3f} -> {float(hist[-1]['loss']):.3f}")


if __name__ == "__main__":
    main()
