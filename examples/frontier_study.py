"""Design frontier: delivered tokens/s vs. effective capex (paper §6.6).

Evaluates every power-delivery design × pod placement quantum on ONE
batched sweep call (device-sharded on a multi-device host), prices each
configuration against the Table 2 model suite via the sweep engine's
metric stage, and prints the Pareto frontier — the paper's argument that
the planning objective is $/performance, not installed MW, in one table.

    PYTHONPATH=src python examples/frontier_study.py --scale 0.01
    PYTHONPATH=src python examples/frontier_study.py --model MoE-401T
    PYTHONPATH=src python examples/frontier_study.py --pods 1 3 5 7
    PYTHONPATH=src python examples/frontier_study.py --plot frontier.png

The --plot figure (delivered TPS vs. capex, frontier highlighted) needs
matplotlib; without it the flag degrades gracefully to the table.
"""
import argparse
import time

import jax

from repro.core import payoff, throughput as tp
from repro.core.arrivals import EnvelopeSpec


def plot(pts, model, path):
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        print(f"# matplotlib unavailable, skipping {path}")
        return
    fig, ax = plt.subplots(figsize=(6, 4))
    dom = [p for p in pts if p.dominated]
    front = sorted((p for p in pts if not p.dominated),
                   key=lambda p: p.total_capex)
    ax.scatter([p.total_capex / 1e9 for p in dom],
               [p.delivered_tps / 1e6 for p in dom],
               c="lightgray", label="dominated")
    ax.plot([p.total_capex / 1e9 for p in front],
            [p.delivered_tps / 1e6 for p in front],
            "o-", c="tab:blue", label="Pareto frontier")
    for p in pts:
        ax.annotate(f"{p.design} p{p.pod_racks}",
                    (p.total_capex / 1e9, p.delivered_tps / 1e6),
                    fontsize=7, xytext=(3, 3), textcoords="offset points")
    ax.set_xlabel("effective capex [$B]")
    ax.set_ylabel(f"delivered tokens/s [M], {model}")
    ax.legend()
    fig.tight_layout()
    fig.savefig(path, dpi=150)
    print(f"# wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01,
                    help="EnvelopeSpec.demand_scale (1.0 = full 10 GW)")
    ap.add_argument("--pods", nargs="+", type=int, default=[1, 5],
                    help="pod placement quanta (racks)")
    ap.add_argument("--model", default="MoE-132T",
                    choices=sorted(tp.MODELS),
                    help="Table 2 model the frontier table reports")
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    ap.add_argument("--plot", default=None, metavar="PNG",
                    help="write the frontier figure (needs matplotlib)")
    args = ap.parse_args()

    env = EnvelopeSpec(demand_scale=args.scale, gpu_scenario="high")
    t0 = time.time()
    pts = payoff.design_frontier(base_env=env, pod_sizes=tuple(args.pods),
                                 models=[tp.MODELS[args.model]],
                                 seeds=tuple(args.seeds))
    wall = time.time() - t0

    print(f"{'design':7s} {'pods':>4s} {'seed':>4s} {'halls':>5s} "
          f"{'deploy':>7s} {'P90str':>7s} {'TPS':>9s} {'TPS/MWbuilt':>11s} "
          f"{'capex':>7s} {'$/TPS':>8s}  frontier")
    for p in sorted(pts, key=lambda q: (q.dominated, q.total_capex)):
        print(f"{p.design:7s} {p.pod_racks:4d} {p.seed:4d} {p.n_halls:5d} "
              f"{p.deployed_mw:6.0f}M {p.p90_stranding:6.1%} "
              f"{p.delivered_tps:9.2e} {p.tps_per_provisioned_w * 1e6:11.0f} "
              f"{p.total_capex / 1e9:6.2f}B {p.dollars_per_tps:8.2f}"
              f"  {'-' if p.dominated else '*'}")
    print(f"# {len(pts)} configs ({args.model}) in one sweep call over "
          f"{jax.device_count()} device(s), {wall:.1f}s wall")
    if args.plot:
        plot(pts, args.model, args.plot)


if __name__ == "__main__":
    main()
