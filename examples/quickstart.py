"""Quickstart: the paper's pipeline end-to-end in ~a minute.

Evaluates two power-delivery designs (4N/3 distributed vs 3+1 block) the
three ways the paper does: static commissioning metrics, single-hall
Monte Carlo, and a (reduced-scale) fleet lifecycle — then prices an
MoE serving deployment with the throughput model.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import cost, hierarchy, projections as proj
from repro.core import throughput as tp
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet
from repro.core.mc_sweep import MCAxes, mc_sweep


def main():
    d43, d31 = hierarchy.design_4n3(), hierarchy.design_3p1()

    print("== static commissioning metrics (paper §3.1) ==")
    for d in (d43, d31):
        print(f"  {d.name}: HA capacity {d.ha_capacity_kw/1e3:.1f} MW, "
              f"initial ${cost.initial_dollars_per_mw(d)/1e6:.2f}M/MW")

    print("\n== single-hall Monte Carlo (paper §4.4, Fig. 5a) ==")
    # both designs' trials as one batched mc_sweep call (the
    # single-configuration equivalent is singlehall.monte_carlo)
    res = mc_sweep(MCAxes.zip(designs=[d43, d31]), n_trials=8,
                   n_events=400, year=2030, scenario=proj.HIGH)
    for i, d in enumerate((d43, d31)):
        s = res.result(i)["lineup_stranding"]
        print(f"  {d.name}: median UPS stranding {np.median(s):.1%}, "
              f"P99 {np.percentile(s, 99):.1%}")

    print("\n== fleet lifecycle, 200 MW demand (Fig. 5b/13 reduced) ==")
    env = EnvelopeSpec(demand_scale=0.02, gpu_scenario=proj.HIGH)
    for d in (d43, d31):
        r = run_fleet(FleetConfig(d, env, seed=0))
        print(f"  {d.name}: {r.n_halls_built} halls, "
              f"P90 stranding {r.p90_stranding[-1]:.1%}, "
              f"effective ${r.effective_dpm/1e6:.2f}M/MW "
              f"(initial ${r.initial_dpm/1e6:.2f}M)")

    print("\n== MoE serving economics (paper §5.4/6.5) ==")
    m = tp.MODELS["MoE-132T"]
    for pod in (1, 4):
        d = tp.Deployment(proj.KYBER, 2028, pod, proj.HIGH)
        print(f"  {m.name} on {max(pod, d.n_units(m))}-rack "
              f"{'pod' if pod > 1 else 'rack-scale'}: "
              f"{tp.tps_request(m, d):,.0f} tok/s, "
              f"{tp.tps_per_watt(m, d):.2f} tok/s/W "
              f"(f_IB={tp.f_ib(m, d):.2f})")


if __name__ == "__main__":
    main()
