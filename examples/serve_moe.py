"""End-to-end serving driver: batched MoE inference with continuous
batching — the workload class the paper's throughput model (§5.4) prices.

    PYTHONPATH=src python examples/serve_moe.py [--requests 16]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.core import projections as proj, throughput as tp
from repro.models.api import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_seq=96, prompt_len=16)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        engine.submit(Request(rid, rng.integers(0, cfg.vocab, 16),
                              max_new_tokens=24))
    engine.run_until_drained()
    dt = time.time() - t0
    print(f"{args.requests} requests on {args.slots} slots: "
          f"{engine.stats['tokens']} tokens in {dt:.1f}s "
          f"({engine.stats['tokens']/dt:.0f} tok/s measured)")

    # compare against the paper's comparative model at datacenter scale
    m = tp.MODELS["MoE-0.6T"]
    d = tp.Deployment(proj.VERA_RUBIN, 2026, 1)
    print(f"paper-model projection for {m.name} on {d.arch.name}: "
          f"{tp.tps_request(m, d):,.0f} tok/s/rack "
          f"({tp.tps_per_watt(m, d):.2f} tok/s/W)")


if __name__ == "__main__":
    main()
