"""Fleet lifecycle study (paper §6.2–6.3, Figs. 13–15).

Sweeps the four reference designs across GPU TDP scenarios and prints the
lifecycle metrics that separate designs which look identical at
commissioning.  Use --scale 1.0 for the full 10 GW study (hours).

    PYTHONPATH=src python examples/fleet_study.py [--scale 0.03]
"""
import argparse

from repro.core import cost, hierarchy, projections as proj
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--scenarios", nargs="+",
                    default=[proj.LOW, proj.MED, proj.HIGH])
    args = ap.parse_args()

    print(f"{'design':8s} {'tdp':5s} {'halls':>6s} {'deployed':>9s} "
          f"{'P90str':>7s} {'init$/MW':>9s} {'eff$/MW':>9s} {'gap':>6s}")
    for scenario in args.scenarios:
        for name in ("4N/3", "3+1", "10N/8", "8+2"):
            env = EnvelopeSpec(demand_scale=args.scale,
                               gpu_scenario=scenario)
            r = run_fleet(FleetConfig(hierarchy.get_design(name), env,
                                      seed=0))
            gap = r.effective_dpm / r.initial_dpm - 1
            print(f"{name:8s} {scenario:5s} {r.n_halls_built:6d} "
                  f"{r.final_deployed_mw:8.0f}M {r.p90_stranding[-1]:6.1%} "
                  f"{r.initial_dpm/1e6:8.2f}M {r.effective_dpm/1e6:8.2f}M "
                  f"{gap:6.1%}")


if __name__ == "__main__":
    main()
