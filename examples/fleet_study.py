"""Fleet lifecycle study (paper §6.2–6.3, Figs. 13–15).

Sweeps the four reference designs across GPU TDP scenarios as ONE
batched sweep call (design × scenario vmapped lifecycle) and prints the
lifecycle metrics that separate designs which look identical at
commissioning.  Use --scale 1.0 for the full 10 GW study (hours).

On a multi-device host the configuration grid is sharded across all
visible devices (`sharded_sweep`); on one device it runs as a plain
single-device sweep.  To simulate N CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        PYTHONPATH=src python examples/fleet_study.py

    PYTHONPATH=src python examples/fleet_study.py [--scale 0.03]
"""
import argparse
import time

import jax

from repro.core import hierarchy, projections as proj
from repro.core.arrivals import EnvelopeSpec
from repro.core.sweep import SweepAxes, sharded_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.03)
    ap.add_argument("--scenarios", nargs="+",
                    default=[proj.LOW, proj.MED, proj.HIGH])
    args = ap.parse_args()

    names = ("4N/3", "3+1", "10N/8", "8+2")
    combos = [(s, n) for s in args.scenarios for n in names]
    axes = SweepAxes.zip(
        designs=[hierarchy.get_design(n) for _, n in combos],
        envs=[EnvelopeSpec(demand_scale=args.scale, gpu_scenario=s)
              for s, _ in combos])
    t0 = time.time()
    res = sharded_sweep(axes)
    wall = time.time() - t0

    print(f"{'design':8s} {'tdp':5s} {'halls':>6s} {'deployed':>9s} "
          f"{'P90str':>7s} {'init$/MW':>9s} {'eff$/MW':>9s} {'gap':>6s}")
    for i, (scenario, name) in enumerate(combos):
        gap = res.effective_dpm[i] / res.initial_dpm[i] - 1
        print(f"{name:8s} {scenario:5s} {res.n_halls_built[i]:6d} "
              f"{res.final_deployed_mw[i]:8.0f}M "
              f"{res.p90_stranding[i, -1]:6.1%} "
              f"{res.initial_dpm[i]/1e6:8.2f}M "
              f"{res.effective_dpm[i]/1e6:8.2f}M {gap:6.1%}")
    print(f"# {len(combos)} configurations in one sweep call over "
          f"{jax.device_count()} device(s), {wall:.1f}s wall")


if __name__ == "__main__":
    main()
