"""Beyond-the-paper scenario frontier (docs/scenarios.md).

Stresses one reference design across all four scenario families —
demand shocks, correlated-lifetime cohorts, workload-mix / LA-share
sweeps, decommission-wave refresh cycles — plus the paper baseline, as
ONE batched sweep call (device-sharded on a multi-device host), and
prints per-scenario stranding and effective-capex deltas.

    PYTHONPATH=src python examples/scenario_study.py --scale 0.01
    PYTHONPATH=src python examples/scenario_study.py --family shock
    XLA_FLAGS=--xla_force_host_platform_device_count=2 \\
        PYTHONPATH=src python examples/scenario_study.py --scale 0.01
"""
import argparse
import time

import jax

from repro.core import hierarchy, payoff, scenarios as sc
from repro.core.arrivals import EnvelopeSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.01,
                    help="EnvelopeSpec.demand_scale (1.0 = full 10 GW)")
    ap.add_argument("--design", default="3+1",
                    choices=("4N/3", "3+1", "10N/8", "8+2"))
    ap.add_argument("--family", default="all",
                    choices=("all",) + sc.FAMILIES,
                    help="restrict to one scenario family")
    ap.add_argument("--seeds", nargs="+", type=int, default=[0])
    args = ap.parse_args()

    base = EnvelopeSpec(demand_scale=args.scale)
    families = sc.all_families(base)
    if args.family != "all":
        families = {args.family: families[args.family]}

    t0 = time.time()
    pts = payoff.scenario_frontier(hierarchy.get_design(args.design),
                                   base_env=base, seeds=tuple(args.seeds),
                                   families=families)
    wall = time.time() - t0

    print(f"{'family':8s} {'scenario':16s} {'seed':>4s} {'halls':>5s} "
          f"{'deploy':>7s} {'P50str':>7s} {'P90str':>7s} {'dP90':>7s} "
          f"{'dCapex':>7s} {'d$/MW':>7s}")
    last_family = None
    for p in pts:
        if p.family != last_family and last_family is not None:
            print()
        last_family = p.family
        print(f"{p.family:8s} {p.label:16s} {p.seed:4d} {p.n_halls:5d} "
              f"{p.deployed_mw:6.0f}M {p.p50_stranding:6.1%} "
              f"{p.p90_stranding:6.1%} {p.d_p90:+6.1%} {p.d_capex:+6.1%} "
              f"{p.d_dpm:+6.1%}")
    print(f"# {len(pts)} scenarios in one sweep call over "
          f"{jax.device_count()} device(s), {wall:.1f}s wall")


if __name__ == "__main__":
    main()
