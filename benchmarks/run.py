"""Benchmark harness — one function per paper table/figure.

Each benchmark prints CSV rows:  name,us_per_call,derived
where `us_per_call` is the wall-time of one underlying simulator/model
call and `derived` is the figure's headline quantity, so the paper's
claims are checkable from the output.

    PYTHONPATH=src python -m benchmarks.run                 # all, reduced scale
    PYTHONPATH=src python -m benchmarks.run --only fig13
    PYTHONPATH=src python -m benchmarks.run --scale 1.0     # full 10 GW study
    PYTHONPATH=src python -m benchmarks.run --json BENCH.json  # + JSON rows

The 10 GW headline study (--scale 1.0) takes hours on this 1-core
container; the default 0.04 (400 MW) preserves every qualitative ranking
(fractions are scale-stable — see tests/test_fleet.py).

Fleet lifecycles are served from `_FLEET_CACHE`, which the fig
benchmarks fill in batches via the sweep engine (`repro.core.sweep`):
each fig prefetches its whole configuration grid as one vmapped call,
sharded across all visible devices (`sharded_sweep`).  The single-hall
figs (5–7) run the same way through `repro.core.mc_sweep` — one batched
call per figure grid.  See benchmarks/README.md for the CSV schema, the
`--json` perf-trajectory dump, and the `sweep_speedup` / `mc_speedup` /
`pod_sweep_speedup` / `placement_kernel_speedup` acceptance modes.
"""
from __future__ import annotations

import argparse
import json
import os
import resource
import subprocess
import sys
import time
from dataclasses import replace
from typing import Dict

import numpy as np

from repro.core import (arrivals, cost, fleet, hierarchy, payoff,
                        placement, projections as proj, quantiles as qt,
                        singlehall, throughput as tp)
from repro.core.arrivals import EnvelopeSpec, generate_fleet_trace
from repro.core.fleet import FleetConfig, run_fleet
from repro.core.mc_sweep import MCAxes, sharded_mc_sweep
from repro.core.sweep import SweepAxes, sharded_sweep, sweep

REGISTRY = {}
_FLEET_CACHE: Dict[tuple, fleet.FleetResult] = {}
_ROWS: Dict[str, dict] = {}
SCALE = 0.04
SMOKE = False


def bench(fn):
    REGISTRY[fn.__name__] = fn
    return fn


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)
    _ROWS[name] = {"us_per_call": float(f"{us:.1f}"),
                   "derived": str(derived)}


def _req(design_name, scenario=proj.MED, pod_racks=1, quantum=10,
         harvest=True, seed=0, scale=None):
    """Normalized fleet-configuration request (also the cache key)."""
    return dict(design_name=design_name, scenario=scenario,
                pod_racks=pod_racks, quantum=quantum, harvest=harvest,
                seed=seed, scale=scale or SCALE)


def _env_of(r):
    return EnvelopeSpec(demand_scale=r["scale"], gpu_scenario=r["scenario"],
                        pod_racks=r["pod_racks"], quantum_racks=r["quantum"],
                        pod_scale_arch=r["pod_racks"] > 1)


def _prefetch(reqs):
    """Batch-evaluate all not-yet-cached fleet configurations through the
    sweep engine: one vmapped lifecycle call per (harvest, pods) group
    instead of one host-driven run per configuration, sharded across all
    visible devices (`sharded_sweep`; single-device passthrough on this
    1-core container).  Pod-free groups stay separate so they compile the
    cheap biased-placement path."""
    seen, miss = set(), []
    for r in reqs:
        k = tuple(sorted(r.items()))
        if k not in _FLEET_CACHE and k not in seen:
            seen.add(k)
            miss.append(r)
    groups = {}
    for r in miss:
        groups.setdefault((r["harvest"], r["pod_racks"] > 1), []).append(r)
    for (hv, _), grp in groups.items():
        axes = SweepAxes.zip(
            designs=[hierarchy.get_design(r["design_name"]) for r in grp],
            envs=[_env_of(r) for r in grp],
            seeds=[r["seed"] for r in grp])
        t0 = time.time()
        res = sharded_sweep(axes, harvest=hv)
        wall = (time.time() - t0) / len(grp)   # amortized per configuration
        for i, r in enumerate(grp):
            fr = res.result(i)
            fr._wall = wall
            _FLEET_CACHE[tuple(sorted(r.items()))] = fr


def _fleet(design_name, scenario=proj.MED, pod_racks=1, quantum=10,
           harvest=True, seed=0, scale=None):
    r = _req(design_name, scenario, pod_racks, quantum, harvest, seed, scale)
    key = tuple(sorted(r.items()))
    if key not in _FLEET_CACHE:
        _prefetch([r])
    return _FLEET_CACHE[key]


# ---------------------------------------------------------------------------


@bench
def fig5_stranding_cdf():
    """CDF of UPS stranding: single-hall MC vs fleet lifecycle (Fig. 5).
    Both designs' MC trials run as ONE batched `mc_sweep` call."""
    dnames = ("4N/3", "3+1")
    t0 = time.time()
    mc = sharded_mc_sweep(
        MCAxes.zip(designs=[hierarchy.get_design(d) for d in dnames],
                   seeds=[5]),
        n_trials=16, n_events=500, year=2030, scenario=proj.HIGH)
    us = (time.time() - t0) / (len(dnames) * 16) * 1e6   # per trial
    for i, dname in enumerate(dnames):
        s = mc.result(i)["lineup_stranding"].flatten()
        emit(f"fig5.mc.{dname}", us,
             f"p50={np.percentile(s, 50):.3f};p99={np.percentile(s, 99):.3f}")
    _prefetch([_req(d, proj.HIGH) for d in dnames])
    for dname in dnames:
        r = _fleet(dname, proj.HIGH)
        s = r.final_lineup_stranding
        emit(f"fig5.lifecycle.{dname}", r._wall * 1e6,
             f"p50={np.percentile(s, 50):.3f};p99={np.percentile(s, 99):.3f};"
             f"halls={r.n_halls_built}")


def _fig6_axes(seed=6):
    """The Fig. 6 grid: 21-point SKU-kW sweep × 2 designs, designs-major."""
    kws = np.arange(200, 2501, 115)
    designs = [hierarchy.get_design(d) for d in ("4N/3", "3+1")]
    return kws, MCAxes.product(designs=designs,
                               sku_kw=[float(k) for k in kws], seeds=(seed,))


@bench
def fig6_single_sku_sweep():
    """Single-hall single-SKU stranding vs deployment power (Fig. 6).
    The whole per-kW loop — 21 kW points × 2 designs — is ONE batched
    `mc_sweep` call over the grid."""
    kws, axes = _fig6_axes()
    t0 = time.time()
    res = sharded_mc_sweep(axes, n_trials=4, n_events=300, harvest=False,
                           single_sku_gpu=True)
    us = (time.time() - t0) / len(axes) * 1e6   # amortized per grid point
    for di, dname in enumerate(("4N/3", "3+1")):
        vals = []
        for ki in range(len(kws)):
            r = res.result(di * len(kws) + ki)
            vals.append(1.0 - r["deployed_kw"].mean() / r["ha_capacity_kw"])
        tops = ",".join(f"{k}:{v:.2f}" for k, v in
                        zip(kws.tolist(), vals) if v > 0.15)
        emit(f"fig6.{dname}", us, f"max_strand={max(vals):.3f};spikes>{{0.15}}=[{tops}]")


@bench
def fig7_placement_policies():
    """Placement-policy comparison (Fig. 7): variance-min lowest.
    All 4 policies × 2 designs run as ONE batched `mc_sweep` call."""
    dnames = ("10N/8", "8+2")
    t0 = time.time()
    res = sharded_mc_sweep(
        MCAxes.product(designs=[hierarchy.get_design(d) for d in dnames],
                       policies=range(4), seeds=(7,)),
        n_trials=8, n_events=900)
    us = (time.time() - t0) / (len(res) * 8) * 1e6   # per trial
    results = {}
    for pol in range(4):
        agg = [res.result(di * 4 + pol)["lineup_stranding"].mean()
               for di in range(len(dnames))]
        results[placement.POLICY_NAMES[pol]] = float(np.mean(agg))
        emit(f"fig7.{placement.POLICY_NAMES[pol]}", us,
             f"mean_lineup_stranding={np.mean(agg):.4f}")
    best = min(results, key=results.get)
    emit("fig7.best_policy", 0, best)


@bench
def fig9_validation():
    """Simulator self-validation (Fig. 9): the paper validates against
    proprietary Azure traces; here a synthetic ground-truth harness —
    re-simulating a held-out seed must reproduce the unused-power
    distribution (median gap < 6%, the paper's own tolerance)."""
    t0 = time.time()
    _prefetch([_req("4N/3", proj.MED, seed=s) for s in (11, 12)])
    ra = _fleet("4N/3", proj.MED, seed=11)
    rb = _fleet("4N/3", proj.MED, seed=12)
    us = (time.time() - t0) * 1e6
    med_a = np.median(ra.final_hall_stranding)
    med_b = np.median(rb.final_hall_stranding)
    gap = abs(med_a - med_b) / max(med_a, 1e-3)
    emit("fig9.selfvalidation", us, f"median_gap={gap:.3f};pass={gap < 0.3}")


@bench
def table5_projections():
    """GPU rack power trajectories (Fig. 12 / Table 5)."""
    t0 = time.time()
    rows = []
    for year in (2026, 2030, 2034):
        rows.append(f"{year}:" + "/".join(
            f"{proj.gpu_rack_kw(year, s):.0f}" for s in proj.SCENARIOS))
    emit("table5.oberon", (time.time() - t0) * 1e6, ";".join(rows))
    rows = [f"{y}:" + "/".join(f"{proj.gpu_rack_kw(y, s, True):.0f}"
                               for s in proj.SCENARIOS)
            for y in (2027, 2030, 2034)]
    emit("table5.kyber", 0, ";".join(rows))


@bench
def fig13_tail_stranding():
    """P90 site stranding over the lifecycle per design × TDP (Fig. 13)."""
    final = {}
    _prefetch([_req(d, s) for s in (proj.LOW, proj.MED, proj.HIGH)
               for d in ("4N/3", "3+1", "10N/8", "8+2")])
    for scenario in (proj.LOW, proj.MED, proj.HIGH):
        for dname in ("4N/3", "3+1", "10N/8", "8+2"):
            r = _fleet(dname, scenario)
            p90 = r.p90_stranding[-1]
            final[(dname, scenario)] = p90
            emit(f"fig13.{dname}.{scenario}", r._wall * 1e6,
                 f"p90_final={p90:.3f};halls={r.n_halls_built};"
                 f"trajectory={','.join(f'{v:.2f}' for v in r.p90_stranding[::24])}")
    sep = final[("3+1", proj.HIGH)] - final[("4N/3", proj.HIGH)]
    emit("fig13.separation_high", 0,
         f"3+1_minus_4N/3={sep:.3f};paper_claims_positive={sep > 0}")


@bench
def fig14_cost_decomposition():
    """Effective-cost decomposition: reserve vs stranding (Fig. 14)."""
    _prefetch([_req(d, proj.HIGH) for d in ("4N/3", "3+1", "10N/8", "8+2")])
    for dname in ("4N/3", "3+1", "10N/8", "8+2"):
        d = hierarchy.get_design(dname)
        r = _fleet(dname, proj.HIGH)
        reserve = cost.reserve_cost_per_mw(d) / 1e6
        strand = cost.stranding_cost_per_mw(
            d, r.n_halls_built, r.final_deployed_mw) / 1e6
        emit(f"fig14.{dname}", r._wall * 1e6,
             f"base=${r.initial_dpm/1e6:.2f}M;reserve=${reserve:.2f}M;"
             f"stranding=${strand:.2f}M;effective=${r.effective_dpm/1e6:.2f}M")


@bench
def fig15_quantization_thresholds():
    """P90 stranding vs effective per-domain deployment power (Fig. 15)."""
    d = hierarchy.get_design("3+1")
    lineup = d.lineup_kw
    _prefetch([_req("3+1", s, pod_racks=p) for p in (1, 3, 5)
               for s in (proj.MED, proj.HIGH)])
    for pod in (1, 3, 5):
        for scenario in (proj.MED, proj.HIGH):
            r = _fleet("3+1", scenario, pod_racks=pod)
            rack = proj.gpu_rack_kw(2030, scenario, pod_scale=pod > 1)
            per_dom = rack * pod
            q = lineup / per_dom
            emit(f"fig15.3+1.pod{pod}.{scenario}", r._wall * 1e6,
                 f"per_domain_kw={per_dom:.0f};C_over_P={q:.2f};"
                 f"p90={r.p90_stranding[-1]:.3f}")


@bench
def fig16_operational_levers():
    """Operational levers vs baseline (Fig. 16)."""
    _prefetch([_req("3+1", proj.HIGH, quantum=q, harvest=hv)
               for q in (10, 5) for hv in (False, True)])
    base = _fleet("3+1", proj.HIGH, quantum=10, harvest=False)
    base_cost = base.total_capex
    for name, kw in (("smaller_quanta", dict(quantum=5, harvest=False)),
                     ("harvesting", dict(quantum=10, harvest=True)),
                     ("both", dict(quantum=5, harvest=True))):
        r = _fleet("3+1", proj.HIGH, **kw)
        delta = (r.total_capex - base_cost) / base_cost
        emit(f"fig16.{name}", r._wall * 1e6,
             f"cost_delta={delta:+.3%};halls={r.n_halls_built} vs "
             f"{base.n_halls_built}")


@bench
def fig17_pareto():
    """Effective fleet cost vs TPS/W for MoE-132T (Fig. 17)."""
    m = tp.MODELS["MoE-132T"]
    _prefetch([_req(d, proj.HIGH, pod_racks=p)
               for d in ("10N/8", "8+2") for p in (1, 3, 5, 7)])
    for dname in ("10N/8", "8+2"):
        for pod in (1, 3, 5, 7):
            r = _fleet(dname, proj.HIGH, pod_racks=pod)
            d = tp.Deployment(proj.KYBER, 2028, max(pod, 1), proj.HIGH)
            tw = tp.tps_per_watt(m, d)
            emit(f"fig17.{dname}.pod{pod}", r._wall * 1e6,
                 f"eff$/MW={r.effective_dpm/1e6:.2f}M;tps_per_w={tw:.3f}")


@bench
def fig18_pod_payoff():
    """Pod payoff across model sizes (Fig. 18)."""
    _prefetch([_req(d, proj.HIGH, pod_racks=p)
               for d in ("10N/8", "8+2") for p in (1, 5)])
    for dname in ("10N/8", "8+2"):
        cache = {p: _fleet(dname, proj.HIGH, pod_racks=p)
                 for p in (1, 5)}
        base_cost = cache[1].effective_dpm
        for mname in ("MoE-0.6T", "MoE-19T", "MoE-132T", "MoE-401T"):
            m = tp.MODELS[mname]
            _, d_tps = payoff.serving_gain(m, 5, 2028)
            d_cost = cache[5].effective_dpm / base_cost - 1
            po = (1 + d_tps) / (1 + d_cost) - 1
            emit(f"fig18.{dname}.{mname}", 0,
                 f"dTPS/W={d_tps:+.3f};dCost={d_cost:+.3f};payoff={po:+.3f}")


@bench
def table2_throughput():
    """Model-suite serving throughput (Table 2 / §5.4 model)."""
    d = tp.Deployment(proj.KYBER, 2028, 1, proj.MED)
    for m in tp.MODEL_SUITE:
        t0 = time.time()
        t = float(tp.tps_request(m, d))
        us = (time.time() - t0) * 1e6
        which, _ = tp.bottleneck(m, d, "dec")
        emit(f"table2.{m.name}", us,
             f"tps={t:,.0f};tps_per_w={tp.tps_per_watt(m, d):.3f};"
             f"n_dom={tp.n_domains(m, d)};bottleneck={which}")


def _speedup_grid(scale, seeds):
    """Fresh 8-configuration (design × scenario × seed) grid shared by the
    `sweep_speedup` legs; distinct seed pairs give distinct traces so the
    bucketed jit cache, not the trace, is what carries between grids."""
    combos = [(d, s, sd) for d in ("4N/3", "3+1")
              for s in (proj.MED, proj.HIGH) for sd in seeds]
    return combos, SweepAxes.zip(
        designs=[hierarchy.get_design(d) for d, _, _ in combos],
        envs=[EnvelopeSpec(demand_scale=scale, gpu_scenario=s)
              for _, s, _ in combos],
        seeds=[sd for _, _, sd in combos])


def _sharded_probe(scale):
    """Sharded-vs-single-device leg of `sweep_speedup`: requires ≥2
    (possibly simulated) devices in THIS process.  Warms both paths on
    one grid, then times a fresh grid each way and emits the ratio.
    Traces are generated once per grid and shared by both legs, so the
    (serial, host-side) trace synthesis cost does not dilute the
    device-execution ratio."""
    import jax

    D = jax.device_count()
    if D < 2:
        emit("sweep.sharded_speedup", 0,
             f"skipped=needs>=2_devices;n_devices={D}")
        return

    def traces_for(axes):
        return [arrivals.generate_fleet_trace(e, s)
                for e, s in zip(axes.envs, axes.seeds)]

    _, warm_axes = _speedup_grid(scale, (201, 202))
    warm_traces = traces_for(warm_axes)
    sweep(warm_axes, traces=warm_traces)
    sharded_sweep(warm_axes, traces=warm_traces)

    combos, axes = _speedup_grid(scale, (203, 204))
    traces = traces_for(axes)
    t0 = time.time()
    res_1 = sweep(axes, traces=traces)
    t_single = time.time() - t0
    t0 = time.time()
    res_d = sharded_sweep(axes, traces=traces)
    t_shard = time.time() - t0

    dev = max(abs(float(res_d.final_deployed_mw[i]) -
                  float(res_1.final_deployed_mw[i]))
              / max(float(res_1.final_deployed_mw[i]), 1e-9)
              for i in range(len(combos)))
    emit("sweep.single_device", t_single / len(combos) * 1e6,
         f"n_cfg={len(combos)};wall_s={t_single:.2f}")
    emit("sweep.sharded", t_shard / len(combos) * 1e6,
         f"n_cfg={len(combos)};n_devices={D};wall_s={t_shard:.2f}")
    emit("sweep.sharded_speedup", 0,
         f"single_over_sharded={t_single / t_shard:.2f}x;"
         f"n_devices={D};max_rel_dev={dev:.2e}")


@bench
def sweep_speedup():
    """Acceptance (ISSUE 1): one jitted/vmapped sweep call evaluates an
    8-configuration (design × scenario × seed) grid; per-configuration
    outputs must agree with sequential `run_fleet` and the wall-time
    ratio is emitted.  A warm-up grid with different seeds runs first so
    both paths are measured on a FRESH grid: the bucketed sweep hits the
    jit cache, while sequential lifecycles recompile per trace shape —
    exactly the workflow the sweep engine batches.

    Acceptance (ISSUE 2): additionally emits the sharded-vs-single-device
    ratio (`sweep.sharded_speedup`) on ≥2 devices.  When this process
    sees only one device, the sharded leg re-runs in a subprocess with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` (host devices
    are time-sliced cores there, so the ratio measures overhead, not
    speedup — real scaling needs real devices)."""
    scale = min(SCALE, 0.01)

    _, warm_axes = _speedup_grid(scale, (101, 102))
    t0 = time.time()
    sweep(warm_axes)
    t_compile = time.time() - t0

    combos, axes = _speedup_grid(scale, (103, 104))
    t0 = time.time()
    res = sweep(axes)
    t_batched = time.time() - t0
    t0 = time.time()
    seq = [run_fleet(axes.config(i)) for i in range(len(combos))]
    t_seq = time.time() - t0

    dev = max(abs(float(res.final_deployed_mw[i]) - r.final_deployed_mw)
              / max(r.final_deployed_mw, 1e-9) for i, r in enumerate(seq))
    halls_ok = all(int(res.n_halls_built[i]) == r.n_halls_built
                   for i, r in enumerate(seq))
    emit("sweep.batched", t_batched / len(combos) * 1e6,
         f"n_cfg={len(combos)};wall_s={t_batched:.2f};"
         f"compile_s={t_compile:.2f}")
    emit("sweep.sequential", t_seq / len(combos) * 1e6,
         f"wall_s={t_seq:.2f}")
    emit("sweep.speedup", 0,
         f"seq_over_batched={t_seq / t_batched:.2f}x;"
         f"max_rel_dev={dev:.2e};halls_match={halls_ok}")

    import jax
    if jax.device_count() >= 2:
        _sharded_probe(scale)
    else:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                            " --xla_force_host_platform_device_count=2"
                            ).strip()
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.run", "--sharded-probe",
             "--scale", str(SCALE)], env=env)
        if r.returncode != 0:
            emit("sweep.sharded_speedup", 0,
                 f"error=probe_subprocess_rc{r.returncode}")


_LEGACY_MC_JIT = None


def _legacy_monte_carlo_fig6(design, n_trials, n_events, seed, sku_kw):
    """Pre-refactor `singlehall.monte_carlo` reference, kept verbatim as
    the sequential baseline `mc_speedup` measures against: per-trial
    host-side Python-loop trace synthesis (`sample_mixed_trace`) with
    post-hoc single-SKU in-place mutation, then one per-point jitted
    trial batch.  Returns the mean deployed kW."""
    global _LEGACY_MC_JIT
    import functools
    import jax
    import jax.numpy as jnp
    from repro.core import placement as pl
    from repro.core.singlehall import TraceArrays, run_trial

    if _LEGACY_MC_JIT is None:
        @functools.partial(jax.jit, static_argnames=("policy", "harvest"))
        def _run(jt, init, ta, tb, keys, policy, harvest):
            return jax.vmap(lambda a, b, k: run_trial(
                jt, init, a, b, policy, k, harvest))(ta, tb, keys)
        _LEGACY_MC_JIT = _run

    topo = hierarchy.build_topology(design)
    jt = pl.jax_topology(topo)
    init = pl.init_state(topo)
    tas, tbs = [], []
    for i in range(n_trials):
        t = arrivals.sample_mixed_trace(n_events, 2028, proj.MED,
                                        seed + 7919 * i, 1.0, 1, 10)
        t.rack_kw[:] = sku_kw
        t.class_id[:] = 0
        t.is_gpu[:] = True
        tas.append(t)
        tb = arrivals.sample_mixed_trace(max(200, n_events // 3), 2028,
                                         proj.MED, seed + 7919 * i + 1,
                                         1.0, 1, 10)
        tb.rack_kw[:] = sku_kw
        tb.is_gpu[:] = True
        tbs.append(tb)
    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *[TraceArrays.from_trace(t) for t in ts])
    ta, tb = stack(tas), stack(tbs)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_trials)
    state, _, _ = _LEGACY_MC_JIT(jt, init, ta, tb, keys,
                                 placement.DEFAULT_POLICY, False)
    return float(jax.vmap(pl.deployed_kw)(state).mean())


@bench
def mc_speedup():
    """Acceptance (ISSUE 4): the Fig. 6 grid (21 SKU-kW points × 2
    designs) evaluated as ONE batched `mc_sweep` call vs the pre-refactor
    sequential path (one `monte_carlo`-style call per grid point, each
    synthesizing its trial traces in a host-side Python loop —
    `_legacy_monte_carlo_fig6`).  A warm-up grid with a different seed
    runs first so both legs are measured compiled; the batched outputs
    are additionally cross-checked against the new per-point
    `monte_carlo` wrapper (identical generator → deviation must be 0)."""
    from repro.core.mc_sweep import mc_sweep

    kw = dict(n_trials=4, n_events=300, harvest=False, single_sku_gpu=True)
    _, warm_axes = _fig6_axes(seed=60)
    mc_sweep(warm_axes, **kw)
    for d in (warm_axes.designs[0], warm_axes.designs[-1]):
        _legacy_monte_carlo_fig6(d, 4, 300, 60, 200.0)

    kws, axes = _fig6_axes(seed=61)
    t0 = time.time()
    res = mc_sweep(axes, **kw)
    t_batched = time.time() - t0
    t0 = time.time()
    seq = [_legacy_monte_carlo_fig6(axes.designs[i], 4, 300, 61,
                                    axes.sku_kw[i])
           for i in range(len(axes))]
    t_seq = time.time() - t0

    # exactness vs the new wrapper on sampled grid points (same batched
    # generator, so the deviation must be 0), and the statistical gap of
    # the legacy RNG's derived stranding (info only)
    wrap_dev = 0.0
    for i in (0, len(kws) - 1, len(kws), len(axes) - 1):
        w = singlehall.monte_carlo(axes.designs[i], n_trials=4,
                                   n_events=300,
                                   sku_kw_override=axes.sku_kw[i],
                                   single_sku_gpu=True, harvest=False,
                                   seed=axes.seeds[i])
        wrap_dev = max(wrap_dev,
                       float(np.abs(res.result(i)["deployed_kw"]
                                    - w["deployed_kw"]).max()))
    strand = lambda dep, i: 1.0 - dep / float(res.ha_capacity_kw[i])
    stat_gap = float(np.mean([abs(strand(res.deployed_kw[i].mean(), i)
                                  - strand(seq[i], i))
                              for i in range(len(axes))]))
    emit("mc.batched", t_batched / len(axes) * 1e6,
         f"n_cfg={len(axes)};n_trials=4;wall_s={t_batched:.2f}")
    emit("mc.sequential", t_seq / len(axes) * 1e6,
         f"wall_s={t_seq:.2f};reference=pre-refactor_python-loop_gen")
    emit("mc.speedup", 0,
         f"seq_over_batched={t_seq / t_batched:.2f}x;"
         f"wrapper_dev={wrap_dev:.2e};legacy_stat_gap={stat_gap:.3f}")


@bench
def pod_sweep_speedup():
    """Acceptance (ISSUE 4): batched pod-grid sweeps through the
    split-trace scan (pods and clusters in separate per-month windows)
    vs the pre-refactor `lax.cond(is_pod, …)` + retry path
    (`legacy_pod_cond=True`), on a fresh 8-configuration
    (design × pod size × seed) grid with shared traces.  The two paths
    are exactly equivalent, so max deviation must be 0."""
    scale = min(SCALE, 0.01)

    def grid(seeds):
        combos = [(d, p, sd) for d in ("10N/8", "8+2") for p in (3, 5)
                  for sd in seeds]
        return SweepAxes.zip(
            designs=[hierarchy.get_design(d) for d, _, _ in combos],
            envs=[EnvelopeSpec(demand_scale=scale, gpu_scenario=proj.HIGH,
                               pod_racks=p, pod_scale_arch=True)
                  for _, p, _ in combos],
            seeds=[sd for *_, sd in combos])

    warm = grid((301,))
    warm_traces = [generate_fleet_trace(e, s)
                   for e, s in zip(warm.envs, warm.seeds)]
    sweep(warm, traces=warm_traces)
    sweep(warm, traces=warm_traces, legacy_pod_cond=True)

    axes = grid((302, 303))
    traces = [generate_fleet_trace(e, s)
              for e, s in zip(axes.envs, axes.seeds)]

    def timed(**kw):
        t0 = time.time()
        res = sweep(axes, traces=traces, **kw)
        return res, time.time() - t0

    # two interleaved repetitions, min per leg (1-core wall times are
    # noisy; the compiled executables are cached so reps only re-execute)
    res_split, t_split = timed()
    res_legacy, t_legacy = timed(legacy_pod_cond=True)
    t_split = min(t_split, timed()[1])
    t_legacy = min(t_legacy, timed(legacy_pod_cond=True)[1])

    dev = float(np.max(np.abs(res_split.final_deployed_mw
                              - res_legacy.final_deployed_mw)))
    halls_ok = bool(np.array_equal(res_split.n_halls_built,
                                   res_legacy.n_halls_built))
    emit("pod_sweep.split", t_split / len(axes) * 1e6,
         f"n_cfg={len(axes)};wall_s={t_split:.2f}")
    emit("pod_sweep.legacy_cond", t_legacy / len(axes) * 1e6,
         f"wall_s={t_legacy:.2f}")
    emit("pod_sweep.speedup", 0,
         f"legacy_over_split={t_legacy / t_split:.2f}x;"
         f"max_dev={dev:.2e};halls_match={halls_ok}")


@bench
def mc_pod_speedup():
    """Acceptance (ISSUE 5): single-hall pod grids through the split-pods
    fast path (pods-first trace windows + HD-compacted row scan) vs the
    legacy per-event `lax.cond(is_pod, …)` path
    (`mc_sweep(..., legacy_pod_cond=True)`) — identical pods-first traces
    either way, so the two paths are exactly equivalent (max deviation
    must be 0).  The grid covers `pod_racks ∈ {3, 5, 7}` (each pod size
    is its own `mc_sweep` call: the pod quantum is a trace-stream
    parameter), 2 designs × 2 seeds per pod size; a warm-up seed
    compiles both paths first so the timed legs measure execution."""
    from repro.core.mc_sweep import MCAxes, mc_sweep

    pods = (3, 5, 7)
    designs = [hierarchy.get_design(d) for d in ("10N/8", "8+2")]
    kw = dict(n_trials=4, n_events=240, year=2030, scenario=proj.HIGH)
    axes = MCAxes.product(designs=designs, seeds=(51, 52))

    t_split = t_legacy = 0.0
    dev, n_cfg = 0.0, 0
    for p in pods:
        # first pair compiles both paths at the exact grid shape and
        # window statics; the timed reps (min of 2, interleaved — 1-core
        # wall times are noisy) then measure execution + staging only
        rs = mc_sweep(axes, pod_racks=p, **kw)
        rl = mc_sweep(axes, pod_racks=p, legacy_pod_cond=True, **kw)
        dev = max(dev, float(np.abs(rs.deployed_kw - rl.deployed_kw).max()),
                  float(np.abs(rs.lineup_stranding
                               - rl.lineup_stranding).max()))

        def timed(**mode):
            t0 = time.time()
            mc_sweep(axes, pod_racks=p, **mode, **kw)
            return time.time() - t0

        reps = [(timed(), timed(legacy_pod_cond=True)) for _ in range(2)]
        t_split += min(r[0] for r in reps)
        t_legacy += min(r[1] for r in reps)
        n_cfg += len(axes)
    emit("mc_pod.split", t_split / n_cfg * 1e6,
         f"n_cfg={n_cfg};pods={'/'.join(map(str, pods))};"
         f"wall_s={t_split:.2f}")
    emit("mc_pod.legacy_cond", t_legacy / n_cfg * 1e6,
         f"wall_s={t_legacy:.2f}")
    emit("mc_pod.speedup", 0,
         f"legacy_over_split={t_legacy / t_split:.2f}x;"
         f"max_dev={dev:.2e}")


@bench
def placement_kernel_speedup():
    """Acceptance (ISSUE 7): the fused Pallas placement-score kernel
    behind `use_kernel=True`.

    Always runs the equivalence leg — a pod-heavy single-hall MC grid
    through the kernel path vs the jnp path, every output column compared
    (`max_dev` must be 0; on non-TPU hosts the kernel runs in interpret
    mode).  The timed kernel-vs-jnp ratio is only meaningful where the
    compiled kernel exists, so on non-TPU backends the ratio row is
    emitted as `skipped=` (which `tools/check_speedups.py` ignores)."""
    import jax
    from repro.core.mc_sweep import MCAxes, mc_sweep

    backend = jax.default_backend()
    axes = MCAxes.zip(designs=[hierarchy.get_design("10N/8")], seeds=[9])
    kw = dict(n_trials=2, n_events=60, pod_racks=3, models=())
    t0 = time.time()
    a = mc_sweep(axes, **kw)
    b = mc_sweep(axes, use_kernel=True,
                 kernel_interpret=backend != "tpu", **kw)
    dev = max(float(np.abs(np.asarray(getattr(a, f), np.float32)
                           - np.asarray(getattr(b, f), np.float32)).max())
              for f in ("lineup_stranding", "hall_stranding", "deployed_kw",
                        "saturated", "placed_a", "placed_b"))
    emit("placement_kernel.equivalence", (time.time() - t0) * 1e6,
         f"max_dev={dev:.2e};bitwise={dev == 0.0};backend={backend}")

    if backend != "tpu":
        emit("placement_kernel.speedup", 0,
             f"skipped=non_tpu_backend;backend={backend}")
        return

    kwt = dict(n_trials=8, n_events=400, pod_racks=3, models=())
    mc_sweep(axes, **kwt)
    mc_sweep(axes, use_kernel=True, **kwt)

    def timed(**mode):
        t0 = time.time()
        mc_sweep(axes, **mode, **kwt)
        return time.time() - t0

    reps = [(timed(), timed(use_kernel=True)) for _ in range(2)]
    t_jnp = min(r[0] for r in reps)
    t_k = min(r[1] for r in reps)
    emit("placement_kernel.jnp", t_jnp / kwt["n_trials"] * 1e6,
         f"wall_s={t_jnp:.2f}")
    emit("placement_kernel.kernel", t_k / kwt["n_trials"] * 1e6,
         f"wall_s={t_k:.2f}")
    emit("placement_kernel.speedup", 0,
         f"jnp_over_kernel={t_jnp / t_k:.2f}x;max_dev={dev:.2e}")


@bench
def giant_grid():
    """Acceptance (ISSUE 8): a planet-scale configuration grid — 10⁴
    lifecycles (512 under ``--smoke``) — through the streaming-quantile
    scan (`exact_quantiles=False`) with chunked sharded dispatch.

    The grid reuses a small (scenario × seed) trace pool across all
    configurations (`traces=`; traces depend only on the envelope and
    seed) and a shortened buildout horizon, so grid SIZE — not trace
    synthesis or horizon length — is what the run exercises.  Chunked
    dispatch (`chunk_size`) bounds live memory at one chunk whatever the
    grid size; every chunk shares one compiled executable.

    Rows:
    * ``giant_grid.stream`` — configs/s throughput and peak RSS of the
      streaming chunked run.
    * ``giant_grid.equivalence`` — streaming p50/p90 vs the exact
      post-hoc reduction on a sub-grid; must stay within one histogram
      bin (1/`quantiles.DEFAULT_BINS`).
    * ``giant_grid.mem_speedup`` — per-configuration XLA temp-buffer
      ratio exact/streaming from `compiled.memory_analysis()` (a
      deterministic compiler quantity, unlike 1-core wall-time ratios;
      gated ≥ 1.0 by tools/check_speedups.py, `skipped=` where the
      backend exposes no memory analysis).  The streaming scan carries
      no ``[M, H]`` stranding history, so its temp footprint is flat in
      the horizon while the exact path's grows with it.
    """
    n_cfg = 512 if SMOKE else 10_000
    chunk = 128 if SMOKE else 512
    pool = [(sc, sd) for sc in (proj.MED, proj.HIGH)
            for sd in (41, 42, 43, 44)]
    envs_pool = [EnvelopeSpec(demand_scale=0.01, gpu_scenario=sc,
                              end_year=2028) for sc, _ in pool]
    traces_pool = [generate_fleet_trace(e, sd)
                   for e, (_, sd) in zip(envs_pool, pool)]
    dnames = ("4N/3", "3+1")
    idx = [i % len(pool) for i in range(n_cfg)]
    axes = SweepAxes.zip(
        designs=[hierarchy.get_design(dnames[i % 2]) for i in range(n_cfg)],
        envs=[envs_pool[j] for j in idx],
        seeds=[pool[j][1] for j in idx])
    traces = [traces_pool[j] for j in idx]

    t0 = time.time()
    res = sharded_sweep(axes, traces=traces, exact_quantiles=False,
                        chunk_size=chunk)
    wall = time.time() - t0
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    emit("giant_grid.stream", wall / n_cfg * 1e6,
         f"n_cfg={n_cfg};chunk={chunk};wall_s={wall:.1f};"
         f"cfg_per_s={n_cfg / wall:.0f};peak_rss_mb={rss_mb:.0f}")

    # streaming vs exact on a sub-grid covering every (design, trace)
    # combination in the big grid
    n_sub = 16
    sub = SweepAxes.zip(designs=axes.designs[:n_sub],
                        envs=axes.envs[:n_sub], seeds=axes.seeds[:n_sub])
    exact = sweep(sub, traces=traces[:n_sub])
    tol = 1.0 / qt.DEFAULT_BINS + 1e-6
    dev = 0.0
    for attr in ("p50_stranding", "p90_stranding"):
        e = np.asarray(getattr(exact, attr))
        s = np.asarray(getattr(res, attr))[:n_sub]
        assert (np.isnan(e) == np.isnan(s)).all()
        ok = ~np.isnan(e)
        dev = max(dev, float(np.abs(s[ok] - e[ok]).max()))
    emit("giant_grid.equivalence", 0,
         f"n_sub={n_sub};max_dev={dev:.2e};"
         f"bin_width={1.0 / qt.DEFAULT_BINS:.2e};pass={dev <= tol}")

    # the temp-memory probe uses a small full-horizon grid with a
    # planet-scale static hall cap: the exact path's per-config [M, H]
    # stranding/activation histories are what the streaming scan
    # removes, and their temp-buffer cost shows up in the compiled
    # program's memory analysis (the measured exact−stream delta equals
    # the history bytes; the rest of the temp footprint is shared)
    probe_hmax = 128
    probe_env = EnvelopeSpec(demand_scale=0.01, gpu_scenario=proj.HIGH)
    probe = SweepAxes.zip(
        designs=[hierarchy.get_design(d) for d in dnames],
        envs=[probe_env], seeds=[41, 42])

    def temp_bytes(exact_q):
        from repro.core.sweep import _prepare, _sweep_jit
        args, *_, with_pods, pod_len, hd_scan = _prepare(
            probe, probe_hmax, None)
        compiled = _sweep_jit.lower(
            *args, harvest=True, mature_months=12, with_pods=with_pods,
            legacy_pod_cond=False, pod_scan_len=pod_len, hd_scan=hd_scan,
            use_kernel=placement.resolve_use_kernel(None),
            kernel_interpret=False, exact_quantiles=exact_q,
            quantile_bins=None).compile()
        return int(compiled.memory_analysis().temp_size_in_bytes)

    try:
        b_ex, b_st = temp_bytes(True), temp_bytes(False)
        emit("giant_grid.mem_speedup", 0,
             f"exact_over_stream_temp={b_ex / max(b_st, 1):.2f}x;"
             f"exact_temp_mb={b_ex / 1e6:.2f};"
             f"stream_temp_mb={b_st / 1e6:.2f};"
             f"history_mb={(b_ex - b_st) / 1e6:.2f};"
             f"n_cfg={len(probe)};n_halls_max={probe_hmax}")
    except Exception as e:   # backend without memory_analysis
        emit("giant_grid.mem_speedup", 0,
             f"skipped=memory_analysis_unavailable;"
             f"err={type(e).__name__}")


def _resilience_grid(n_cfg):
    """The giant_grid --smoke geometry (trace pool × 2 designs, short
    horizon) shared by the `resilience_*` legs, so the resume leg and
    the overhead leg reuse one compiled chunk executable."""
    pool = [(sc, sd) for sc in (proj.MED, proj.HIGH)
            for sd in (41, 42, 43, 44)]
    envs_pool = [EnvelopeSpec(demand_scale=0.01, gpu_scenario=sc,
                              end_year=2028) for sc, _ in pool]
    traces_pool = [generate_fleet_trace(e, sd)
                   for e, (_, sd) in zip(envs_pool, pool)]
    idx = [i % len(pool) for i in range(n_cfg)]
    axes = SweepAxes.zip(
        designs=[hierarchy.get_design(("4N/3", "3+1")[i % 2])
                 for i in range(n_cfg)],
        envs=[envs_pool[j] for j in idx],
        seeds=[pool[j][1] for j in idx])
    return axes, [traces_pool[j] for j in idx]


@bench
def resilience_overhead():
    """Acceptance (ISSUE 9): per-chunk checkpointing must cost ≤ ~10%
    over the same chunked run without durability.  Both legs go through
    `resilient_sweep` on the giant_grid --smoke geometry (so the only
    delta is the atomic write-temp→rename→fsync commit per chunk), the
    ratio row carries its own `min=0.9` floor for
    tools/check_speedups.py, and the two results must be bitwise equal
    — durability cannot change a single bit of the output."""
    import shutil
    import tempfile

    from repro.core.resilience import resilient_sweep

    n_cfg, chunk = (128, 32) if SMOKE else (512, 128)
    axes, traces = _resilience_grid(n_cfg)
    kw = dict(chunk_size=chunk, traces=traces, exact_quantiles=False)

    resilient_sweep(axes, **kw)                     # compile warm-up
    t0 = time.time()
    res_off = resilient_sweep(axes, **kw)
    t_off = time.time() - t0

    ckdir = tempfile.mkdtemp(prefix="resilience_bench_")
    try:
        t0 = time.time()
        res_on = resilient_sweep(axes, checkpoint_dir=ckdir, **kw)
        t_on = time.time() - t0
        n_steps = len([n for n in os.listdir(ckdir)
                       if n.startswith("step_")])
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    bitwise = all(
        np.array_equal(np.asarray(getattr(res_off, f)),
                       np.asarray(getattr(res_on, f)))
        for f in ("final_deployed_mw", "deployed_mw", "p90_stranding",
                  "n_halls_built", "total_capex"))
    assert bitwise, "checkpointing changed the sweep output"
    emit("resilience.ckpt_off", t_off / n_cfg * 1e6,
         f"n_cfg={n_cfg};chunk={chunk};wall_s={t_off:.2f}")
    emit("resilience.ckpt_on", t_on / n_cfg * 1e6,
         f"wall_s={t_on:.2f};chunks_committed={n_steps}")
    emit("resilience.overhead_speedup", 0,
         f"ckpt_off_over_on={t_off / t_on:.2f}x;min=0.9;"
         f"bitwise={bitwise}")


@bench
def resilience_resume():
    """Acceptance (ISSUE 9): kill-and-resume on the 512-configuration
    giant_grid --smoke grid — crash injected after chunk 3 commits,
    the resumed run loads the 3 committed chunks, computes the rest and
    must be BITWISE identical to the uninterrupted `sweep()` result
    (asserted here, so the CI resume-smoke leg fails loudly on any
    drift; also exercised per-boundary in tests/test_resilience.py)."""
    import shutil
    import tempfile

    from repro.core.resilience import (FaultPlan, InjectedCrash,
                                       resilient_sweep)

    n_cfg, chunk = (512, 128) if SMOKE else (1024, 256)
    axes, traces = _resilience_grid(n_cfg)
    kw = dict(chunk_size=chunk, traces=traces, exact_quantiles=False)

    ref = sweep(axes, traces=traces, exact_quantiles=False)

    ckdir = tempfile.mkdtemp(prefix="resilience_resume_")
    try:
        t0 = time.time()
        crashed = False
        try:
            resilient_sweep(axes, checkpoint_dir=ckdir,
                            fault_plan=FaultPlan(crash_after=2), **kw)
        except InjectedCrash:
            crashed = True
        assert crashed, "injected crash did not fire"
        res = resilient_sweep(axes, checkpoint_dir=ckdir, **kw)
        wall = time.time() - t0
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)

    fields = ("halls_active", "deployed_mw", "p50_stranding",
              "p90_stranding", "n_halls_built", "final_deployed_mw",
              "placed_fraction", "total_capex", "dollars_per_tps")
    bitwise = all(np.array_equal(np.asarray(getattr(res, f)),
                                 np.asarray(getattr(ref, f)))
                  for f in fields)
    assert bitwise, "resumed sweep diverged from the uninterrupted run"
    r = res.report
    assert r.chunks_resumed == 3, r
    emit("resilience.resume", wall / n_cfg * 1e6,
         f"n_cfg={n_cfg};chunk={chunk};wall_s={wall:.1f};"
         f"chunks_resumed={r.chunks_resumed};"
         f"chunks_computed={r.chunks_computed};bitwise={bitwise}")


@bench
def scenario_sweep():
    """Beyond-the-paper scenario frontier (docs/scenarios.md): baseline +
    all four scenario families (demand shocks, correlated cohorts,
    mix/LA sweeps, refresh waves) on ONE sharded sweep grid; emits
    p50/p90 stranding and effective-capex deltas per scenario."""
    base = EnvelopeSpec(demand_scale=SCALE)
    t0 = time.time()
    pts = payoff.scenario_frontier(hierarchy.get_design("3+1"),
                                   base_env=base)
    us = (time.time() - t0) / len(pts) * 1e6    # amortized per scenario
    for p in pts:
        emit(f"scenario.{p.family}.{p.label}", us,
             f"p50={p.p50_stranding:.3f};p90={p.p90_stranding:.3f};"
             f"halls={p.n_halls};dP90={p.d_p90:+.3f};"
             f"dCapex={p.d_capex:+.3%};d$/MW={p.d_dpm:+.3%}")
    worst = max(pts, key=lambda p: p.p90_stranding)
    n_fam = len({p.family for p in pts}) - 1     # minus the baseline
    emit("scenario.frontier", 0,
         f"n_scenarios={len(pts)};n_families={n_fam};"
         f"worst_p90={worst.family}:{worst.label}={worst.p90_stranding:.3f}")


@bench
def metric_stack():
    """Acceptance (ISSUE 6): the batched $/performance metric stage.

    Times ONE jitted `tps_per_watt_grid` over a deployments × models grid
    (pod sizes × TDP scenarios × the Table 2 suite — the grid the sweep
    engines' metric stage evaluates per call) against the pre-refactor
    path: one eager scalar `tps_request` per (model, deployment) pair.
    Cross-checks the grid against the scalar loop (must agree to float
    tolerance) and smokes `payoff.design_frontier` on its default
    4-design × 2-pod-quanta grid."""
    deps = [tp.Deployment(proj.KYBER, 2028, n, s)
            for s in (proj.MED, proj.HIGH) for n in (1, 3, 5, 7)]
    models = tp.MODEL_SUITE
    tp.tps_per_watt_grid(models, deps).block_until_ready()   # compile
    [float(tp.tps_per_watt(m, d)) for m in models for d in deps[:1]]

    t0 = time.time()
    grid = np.asarray(tp.tps_per_watt_grid(models, deps))
    t_batched = time.time() - t0
    t0 = time.time()
    loop = np.array([[tp.tps_per_watt(m, d) for m in models] for d in deps])
    t_loop = time.time() - t0
    dev = float(np.abs(grid / loop - 1.0).max())
    n = grid.size
    emit("metric_stack.batched", t_batched / n * 1e6,
         f"n_pairs={n};wall_s={t_batched:.3f}")
    emit("metric_stack.loop", t_loop / n * 1e6,
         f"wall_s={t_loop:.3f};reference=eager_scalar_tps_request")
    emit("metric_stack.speedup", 0,
         f"loop_over_batched={t_loop / t_batched:.2f}x;grid_dev={dev:.2e}")

    env = EnvelopeSpec(demand_scale=min(SCALE, 0.01),
                       gpu_scenario=proj.HIGH)
    t0 = time.time()
    pts = payoff.design_frontier(base_env=env,
                                 models=[tp.MODELS["MoE-132T"]])
    us = (time.time() - t0) / len(pts) * 1e6
    front = sorted((p for p in pts if not p.dominated),
                   key=lambda p: p.total_capex)
    emit("metric_stack.frontier", us,
         f"n_points={len(pts)};n_pareto={len(front)};"
         f"best={front[0].design}:pod{front[0].pod_racks}"
         f"=${front[0].dollars_per_tps:.2f}/tps")


@bench
def fig2_overview():
    """Design × workload overview (Fig. 2): TPS/W vs effective $/W."""
    _prefetch([_req(d, proj.HIGH) for d in ("4N/3", "8+2")])
    for dname in ("4N/3", "8+2"):
        r = _fleet(dname, proj.HIGH)
        for mname in ("MoE-0.6T", "MoE-132T"):
            m = tp.MODELS[mname]
            d = tp.Deployment(proj.KYBER, 2028, 1, proj.HIGH)
            emit(f"fig2.{dname}.{mname}", 0,
                 f"tps_per_w={tp.tps_per_watt(m, d):.3f};"
                 f"eff$/W={r.effective_dpm/1e6:.2f}")


def main(argv=None):
    global SCALE, SMOKE
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--scale", type=float, default=0.04)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-size giant_grid (512 configs; the CI "
                         "acceptance gate) instead of the full 10^4")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write {name: {us_per_call, derived}} for "
                         "every emitted row to PATH (machine-readable "
                         "perf trajectory; see benchmarks/README.md)")
    ap.add_argument("--sharded-probe", action="store_true",
                    help="internal: run only the multi-device leg of "
                         "sweep_speedup (expects forced host devices)")
    args = ap.parse_args(argv)
    SCALE = args.scale
    SMOKE = args.smoke
    if args.sharded_probe:
        _sharded_probe(min(SCALE, 0.01))
        return
    print("name,us_per_call,derived")
    for name, fn in REGISTRY.items():
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        fn()
        print(f"# {name} total {time.time() - t0:.1f}s", file=sys.stderr,
              flush=True)
    if args.json:
        # rows emitted by the sweep_speedup sharded-probe *subprocess*
        # appear only in its own CSV stream, not here
        with open(args.json, "w") as f:
            json.dump(_ROWS, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
