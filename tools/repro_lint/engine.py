"""File discovery, parsing, and checker dispatch.

`lint_paths(paths, root)` is the programmatic front door (the CLI in
`__main__` is a thin wrapper): it walks the given files/directories for
`*.py`, parses each once, runs every registered file checker on each
file and every project checker on the whole set, applies inline
suppressions, and returns sorted diagnostics.

The lint *fixture corpus* (`tests/fixtures/lint/`) is skipped by
default — its bad files exist to fail — and re-included with
`include_fixtures=True` (CLI `--include-fixtures`), which is how the CI
smoke leg proves the linter still fires.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from . import pyast, registry, scopes
from .diagnostics import Diagnostic, parse_directives

registry.rule("RL000", "syntax-error",
              "file must parse: a file the checkers cannot read is a "
              "file whose contracts cannot be verified")

_SKIP_DIR_NAMES = {"__pycache__", ".git", ".venv", "node_modules",
                   ".pytest_cache", "build", "dist"}
_FIXTURE_MARKER = ("tests", "fixtures")


class FileContext:
    """Everything a file checker needs about one parsed file."""

    def __init__(self, path: str, scope_path: str, tree: ast.Module,
                 lines: List[str], root: pathlib.Path):
        self.path = path                # repo-relative, for reporting
        self.scope_path = scope_path    # for scope decisions (pragma-able)
        self.tree = tree
        self.lines = lines
        self.root = root
        self.aliases = pyast.import_aliases(tree)
        self.consts = pyast.module_string_tuples(tree)

    def diag(self, node_or_line, code: str, message: str) -> Diagnostic:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        return Diagnostic(self.path, line, code, message)


class ProjectContext:
    """One lint invocation: the parsed file set plus lazily computed
    repo-wide facts (the axes.py allowed-axis table)."""

    def __init__(self, root: pathlib.Path, contexts: Sequence[FileContext]):
        self.root = root
        self.contexts = list(contexts)
        self._allowed_axes: Optional[frozenset] = None

    def allowed_mesh_axes(self) -> Optional[frozenset]:
        """Axis-name strings declared in `sharding/axes.py` (ALL_CAPS
        string constants plus every string key/value in its dict
        literals, tuple elements included).  None when axes.py is not
        available — the mesh-axis rule then stands down rather than
        guessing the contract."""
        if self._allowed_axes is not None:
            return self._allowed_axes
        tree = None
        for ctx in self.contexts:
            if scopes.is_axes_module(ctx.scope_path):
                tree = ctx.tree
                break
        if tree is None:
            path = self.root / scopes.AXES_MODULE
            if not path.is_file():
                return None
            try:
                tree = ast.parse(path.read_text(encoding="utf-8"))
            except SyntaxError:
                return None
        names = set()

        def _add_str(item):
            if isinstance(item, ast.Constant) and isinstance(item.value, str):
                names.add(item.value)

        for node in ast.walk(tree):
            # tuples of axis names: ("pod", "data"), ("data",), …
            if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
                for elt in node.elts:
                    _add_str(elt)
            # rule tables: logical-name keys AND mesh-axis values
            elif isinstance(node, ast.Dict):
                for item in (*node.keys, *node.values):
                    _add_str(item)
            # CONFIG_AXIS = "config"; r["seq"] = "pod"
            elif isinstance(node, ast.Assign):
                for target in node.targets:
                    if isinstance(target, ast.Name) and target.id.isupper():
                        _add_str(node.value)
                    elif isinstance(target, ast.Subscript):
                        _add_str(target.slice)
                        _add_str(node.value)
        self._allowed_axes = frozenset(names)
        return self._allowed_axes


def _is_fixture(rel_parts: Tuple[str, ...]) -> bool:
    for i in range(len(rel_parts) - 1):
        if rel_parts[i:i + 2] == _FIXTURE_MARKER:
            return True
    return False


def discover(paths: Sequence[str], root: pathlib.Path,
             include_fixtures: bool = False) -> List[pathlib.Path]:
    """Expand files/directories into a sorted, de-duplicated list of
    `*.py` files under `root`."""
    out = []
    seen = set()
    for p in paths:
        path = pathlib.Path(p)
        if not path.is_absolute():
            path = root / path
        if path.is_dir():
            candidates = sorted(path.rglob("*.py"))
        elif path.is_file():
            candidates = [path]
        else:
            raise FileNotFoundError(f"no such file or directory: {p}")
        for c in candidates:
            c = c.resolve()
            if c in seen:
                continue
            rel = _relpath(c, root)
            parts = tuple(rel.split("/"))
            if _SKIP_DIR_NAMES.intersection(parts):
                continue
            if not include_fixtures and _is_fixture(parts):
                continue
            seen.add(c)
            out.append(c)
    return out


def _relpath(path: pathlib.Path, root: pathlib.Path) -> str:
    try:
        return scopes.norm(path.resolve().relative_to(root.resolve()))
    except ValueError:
        return scopes.norm(path)


def parse_file(path: pathlib.Path, root: pathlib.Path
               ) -> Tuple[Optional[FileContext], Optional[Diagnostic],
                          Dict[int, set]]:
    """-> (context, parse-error diagnostic, suppressions)."""
    rel = _relpath(path, root)
    source = path.read_text(encoding="utf-8")
    return parse_source(source, rel, root)


def parse_source(source: str, rel: str, root: pathlib.Path):
    lines = source.splitlines()
    suppressions, pragma_path = parse_directives(lines)
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return None, Diagnostic(rel, e.lineno or 1, "RL000",
                                f"syntax error: {e.msg}"), suppressions
    ctx = FileContext(rel, pragma_path or rel, tree, lines, root)
    return ctx, None, suppressions


def _run_checkers(contexts: List[FileContext],
                  root: pathlib.Path) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for ctx in contexts:
        for checker in registry.FILE_CHECKERS:
            diags.extend(checker(ctx))
    project = ProjectContext(root, contexts)
    for checker in registry.PROJECT_CHECKERS:
        diags.extend(checker(project))
    return diags


def lint_paths(paths: Sequence[str], root: pathlib.Path,
               include_fixtures: bool = False) -> List[Diagnostic]:
    """Lint every `*.py` under `paths`; returns sorted diagnostics with
    inline suppressions already applied."""
    from . import checkers  # noqa: F401  (registers all rules)
    contexts: List[FileContext] = []
    diags: List[Diagnostic] = []
    suppressions: Dict[str, Dict[int, set]] = {}
    for path in discover(paths, root, include_fixtures):
        ctx, err, supp = parse_file(path, root)
        if err is not None:
            diags.append(err)
        if ctx is not None:
            contexts.append(ctx)
            suppressions[ctx.path] = supp
    diags.extend(_run_checkers(contexts, root))
    return _filter_suppressed(diags, suppressions)


def lint_source(source: str, path: str, root: pathlib.Path
                ) -> List[Diagnostic]:
    """Lint a single in-memory source file (the unit-test entry point).
    `path` is the reported repo-relative path; a `# repro-lint: path=`
    directive inside `source` still overrides the scope path."""
    from . import checkers  # noqa: F401
    ctx, err, supp = parse_source(source, scopes.norm(path), root)
    if err is not None:
        return [err]
    diags = _run_checkers([ctx], root)
    return _filter_suppressed(diags, {ctx.path: supp})


def _filter_suppressed(diags: Iterable[Diagnostic],
                       suppressions: Dict[str, Dict[int, set]]
                       ) -> List[Diagnostic]:
    out = []
    for d in diags:
        disabled = suppressions.get(d.path, {}).get(d.line, ())
        if d.code in disabled:
            continue
        out.append(d)
    return sorted(set(out))
