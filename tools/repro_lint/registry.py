"""Rule + checker registry.

Each rule has an `RL###` code, a short kebab-case name, and a one-line
summary of the source-level contract it protects.  Checkers come in two
flavors:

* **file checkers** — `fn(ctx: FileContext) -> Iterable[Diagnostic]`,
  run once per parsed file; everything the checker needs is local.
* **project checkers** — `fn(project: ProjectContext) ->
  Iterable[Diagnostic]`, run once per lint invocation; used by rules
  that relate files to each other (kernel/ref parity, cross-module
  jit-static call sites, the axes.py allowed-name table).

Checker modules self-register at import time (see `checkers/__init__`),
so the registry is also the single source of truth for `--list-rules`,
the docs rule catalog test, and the every-rule-has-a-firing-fixture
meta-test.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    summary: str


RULES: Dict[str, Rule] = {}
FILE_CHECKERS: List[Callable] = []
PROJECT_CHECKERS: List[Callable] = []


def rule(code: str, name: str, summary: str) -> Rule:
    if code in RULES:
        raise ValueError(f"duplicate rule code {code}")
    r = Rule(code, name, summary)
    RULES[code] = r
    return r


def file_checker(fn: Callable) -> Callable:
    FILE_CHECKERS.append(fn)
    return fn


def project_checker(fn: Callable) -> Callable:
    PROJECT_CHECKERS.append(fn)
    return fn
