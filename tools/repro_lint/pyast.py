"""Small AST helpers shared by the checkers: dotted-name resolution
through import aliases, and constant folding of string tuples (enough to
resolve `static_argnames=_MC_STATICS + ("mesh",)`)."""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple


def dotted(node: ast.AST) -> Optional[str]:
    """`a.b.c` Attribute/Name chain -> "a.b.c", else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def import_aliases(tree: ast.AST) -> Dict[str, str]:
    """Map local names to the fully-qualified module/attribute they were
    imported as.  `import numpy as np` -> {"np": "numpy"};
    `from jax import random` -> {"random": "jax.random"};
    `from jax.random import split as sp` -> {"sp": "jax.random.split"}.
    Plain `import jax.random` binds only the root name `jax`.
    """
    aliases: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".", 1)[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom):
            mod = ("." * node.level) + (node.module or "")
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{mod}.{a.name}" if mod \
                    else a.name
    return aliases


def resolve(name: Optional[str], aliases: Dict[str, str]) -> Optional[str]:
    """Expand the first segment of a dotted name through the alias map."""
    if name is None:
        return None
    head, _, rest = name.partition(".")
    base = aliases.get(head, head)
    return f"{base}.{rest}" if rest else base


def resolve_call(call: ast.Call, aliases: Dict[str, str]) -> Optional[str]:
    return resolve(dotted(call.func), aliases)


def module_string_tuples(tree: ast.Module) -> Dict[str, Tuple[str, ...]]:
    """Module-level `NAME = ("a", "b", ...)` / `NAME = "a"` constants,
    including concatenations of other such constants — the shapes
    `static_argnames` references take in this repo."""
    consts: Dict[str, Tuple[str, ...]] = {}
    for node in tree.body:
        if (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            folded = fold_strings(node.value, consts)
            if folded is not None:
                consts[node.targets[0].id] = folded
    return consts


def fold_strings(node: ast.AST,
                 consts: Dict[str, Tuple[str, ...]]
                 ) -> Optional[Tuple[str, ...]]:
    """Fold an expression into a tuple of strings, or None if it is not
    statically a string collection.  Handles string constants,
    tuple/list literals, references to previously folded module
    constants, and `+` concatenation."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            folded = fold_strings(elt, consts)
            if folded is None or len(folded) != 1:
                return None
            out.extend(folded)
        return tuple(out)
    if isinstance(node, ast.Name):
        return consts.get(node.id)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left = fold_strings(node.left, consts)
        right = fold_strings(node.right, consts)
        if left is not None and right is not None:
            return left + right
    return None


def fold_ints(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Fold an expression into a tuple of ints (for static_argnums)."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int) \
            and not isinstance(node.value, bool):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[int] = []
        for elt in node.elts:
            folded = fold_ints(elt)
            if folded is None or len(folded) != 1:
                return None
            out.extend(folded)
        return tuple(out)
    return None


def param_names(fndef) -> List[str]:
    """All parameter names, in declaration order (posonly, positional,
    keyword-only)."""
    a = fndef.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def string_args(call: ast.Call):
    """Yield `(lineno, value)` for every string literal appearing as a
    positional argument or inside a tuple/list positional argument."""
    for arg in call.args:
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            yield arg.lineno, arg.value
        elif isinstance(arg, (ast.Tuple, ast.List)):
            for elt in arg.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    yield elt.lineno, elt.value
