"""CLI for repro-lint.

    python -m tools.repro_lint src tests tools
    python -m tools.repro_lint src tests tools --baseline .repro-lint-baseline.json
    python -m tools.repro_lint tests benchmarks --write-baseline .repro-lint-baseline.json
    python -m tools.repro_lint tests/fixtures/lint --include-fixtures   # must fail
    python -m tools.repro_lint --list-rules

Exit codes: 0 = clean (or every finding baselined), 1 = non-baselined
findings, 2 = usage error (missing path, unreadable baseline).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from . import baseline as baseline_mod
from . import registry
from .engine import lint_paths

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2


def _repo_root() -> pathlib.Path:
    return pathlib.Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="trace-safety & determinism static analysis "
                    "(rule catalog: docs/static-analysis.md)")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (relative to the "
                         "repo root)")
    ap.add_argument("--baseline", metavar="FILE",
                    help="tolerate findings recorded in this baseline "
                         "(matched per (path, rule) count)")
    ap.add_argument("--write-baseline", metavar="FILE",
                    help="write current findings to FILE and exit 0")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--include-fixtures", action="store_true",
                    help="also lint tests/fixtures/** (skipped by "
                         "default; the lint fixture corpus is meant to "
                         "fail)")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--root", default=None,
                    help="repo root override (default: inferred from "
                         "this file's location)")
    args = ap.parse_args(argv)

    from . import checkers  # noqa: F401  (populate the registry)
    if args.list_rules:
        for code in sorted(registry.RULES):
            r = registry.RULES[code]
            print(f"{r.code}  {r.name}: {r.summary}")
        return EXIT_CLEAN

    if not args.paths:
        ap.print_usage(sys.stderr)
        print("error: no paths given (and not --list-rules)",
              file=sys.stderr)
        return EXIT_USAGE

    root = pathlib.Path(args.root).resolve() if args.root else _repo_root()
    try:
        diags = lint_paths(args.paths, root,
                           include_fixtures=args.include_fixtures)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return EXIT_USAGE

    if args.write_baseline:
        counts = baseline_mod.write(args.write_baseline, diags)
        print(f"wrote {sum(counts.values())} finding(s) across "
              f"{len(counts)} (path, rule) group(s) to "
              f"{args.write_baseline}")
        return EXIT_CLEAN

    stale = {}
    if args.baseline:
        try:
            counts = baseline_mod.load(args.baseline)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"error: cannot read baseline: {e}", file=sys.stderr)
            return EXIT_USAGE
        reported, stale = baseline_mod.apply(diags, counts)
    else:
        reported = diags

    if args.format == "json":
        print(json.dumps({
            "findings": [d.to_json() for d in reported],
            "baselined": len(diags) - len(reported),
            "stale_baseline": stale,
        }, indent=2))
    else:
        for d in reported:
            print(d.format())
        for key, surplus in sorted(stale.items()):
            print(f"warning: baseline entry {key} over-budgets by "
                  f"{surplus} (finding fixed? shrink the baseline)",
                  file=sys.stderr)
        n_base = len(diags) - len(reported)
        summary = f"{len(reported)} finding(s)"
        if n_base:
            summary += f", {n_base} baselined"
        print(summary)
    return EXIT_FINDINGS if reported else EXIT_CLEAN


if __name__ == "__main__":
    sys.exit(main())
