"""RL401 — the float32 kernel contract.

`kernels/placement_score/ops.py` *rejects* float64 inputs rather than
silently downcasting, so the kernel path can never drift bitwise from
the jnp oracle.  That only holds if kernel-reachable modules never mint
float64 arrays in the first place.  This checker flags float64
*creation* sites — `dtype=float64` keywords, `.astype(float64)`, and
`np.float64(...)`/`jnp.float64(...)` constructor calls — in
kernel-reachable modules (`src/repro/kernels/` plus the core modules
whose arrays flow into kernel calls).  Comparisons like
`x.dtype == jnp.float64` (the guard in ops.py itself) are creation-free
and are not flagged.
"""
from __future__ import annotations

import ast

from .. import registry
from ..pyast import dotted, resolve
from ..scopes import in_kernel_reachable

registry.rule(
    "RL401", "float64-in-kernel-path",
    "kernel-reachable modules must not create float64 arrays: the "
    "placement-score kernel computes in float32 and its ops wrapper "
    "rejects x64 inputs (score_rows contract)")

_F64 = {"numpy.float64", "jax.numpy.float64"}


def _is_float64(node: ast.AST, aliases) -> bool:
    if isinstance(node, ast.Constant):
        return node.value == "float64"
    q = resolve(dotted(node), aliases)
    return q in _F64


@registry.file_checker
def check_dtype64(ctx):
    if not in_kernel_reachable(ctx.scope_path):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        # x.astype(float64-ish)
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr == "astype":
            for arg in (*node.args, *(kw.value for kw in node.keywords)):
                if _is_float64(arg, ctx.aliases):
                    yield ctx.diag(node, "RL401",
                                   ".astype(float64) in kernel-reachable"
                                   " module (float32 kernel contract)")
        # np.float64(x) / jnp.float64(x)
        elif resolve(dotted(node.func), ctx.aliases) in _F64:
            yield ctx.diag(node, "RL401",
                           "float64 scalar/array constructor in "
                           "kernel-reachable module (float32 kernel "
                           "contract)")
        # any call carrying dtype=float64
        for kw in node.keywords:
            if kw.arg == "dtype" and _is_float64(kw.value, ctx.aliases):
                yield ctx.diag(node, "RL401",
                               "dtype=float64 in kernel-reachable "
                               "module (float32 kernel contract)")
