"""Checker modules self-register on import; importing this package is
what populates the registry.  Order matters only for `--list-rules`
display (kept in code order: RL1xx → RL6xx)."""
from . import (jit_static, determinism, prng, dtype64, kernel_parity,
               mesh_axes)  # noqa: F401
