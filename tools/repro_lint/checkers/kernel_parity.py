"""RL5xx — kernel/ref pair parity.

Every Pallas kernel package `…/kernels/<name>/` ships three modules:
`kernel.py` (the Pallas implementation), `ref.py` (the pure-jnp oracle
the bitwise harness tests against), and `ops.py` (the jitted wrapper,
which must expose an `interpret` path so CPU CI can run the kernel
without a TPU).  The equivalence harness is only as good as this
structure, so the linter enforces it:

* **RL501** — `kernel.py` without a sibling `ref.py`.
* **RL502** — no public `ref.py` function whose parameter names are an
  ordered subset of a public `kernel.py` function's parameters (the
  oracle mirrors the kernel's argument convention; the kernel may add
  trailing tuning knobs like `block_r`/`interpret`).
* **RL503** — missing `ops.py`, or no public `ops.py` function taking
  an `interpret` parameter.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Dict, List, Optional

from .. import registry
from ..pyast import param_names
from ..scopes import norm

registry.rule(
    "RL501", "kernel-missing-ref",
    "every kernels/<name>/kernel.py needs a ref.py jnp oracle: the "
    "bitwise equivalence harness is the kernel's correctness proof")
registry.rule(
    "RL502", "kernel-ref-signature-mismatch",
    "ref.py must expose a public function whose parameters mirror the "
    "kernel entry point (ordered subset; kernel-only tuning knobs like "
    "block sizes/interpret may trail)")
registry.rule(
    "RL503", "ops-missing-interpret",
    "kernels/<name>/ops.py must exist and expose an `interpret` "
    "parameter so CPU CI can prove kernel ≡ oracle without a TPU")


def _public_functions(tree: ast.Module) -> List[ast.FunctionDef]:
    return [node for node in tree.body
            if isinstance(node, ast.FunctionDef)
            and not node.name.startswith("_")]


def _ordered_subset(small: List[str], big: List[str]) -> bool:
    pos = 0
    for name in small:
        try:
            pos = big.index(name, pos) + 1
        except ValueError:
            return False
    return True


def _parse_sibling(project, directory: str, filename: str,
                   by_path: Dict[str, ast.Module]) -> Optional[ast.Module]:
    """The sibling module's AST: from the scanned set if present, else
    parsed off disk (covers single-file lint invocations)."""
    rel = f"{directory}/{filename}" if directory else filename
    if rel in by_path:
        return by_path[rel]
    path = pathlib.Path(project.root) / rel
    if not path.is_file():
        return None
    try:
        return ast.parse(path.read_text(encoding="utf-8"))
    except SyntaxError:
        return None


@registry.project_checker
def check_kernel_parity(project):
    by_path = {norm(ctx.path): ctx.tree for ctx in project.contexts}
    for ctx in project.contexts:
        rel = norm(ctx.path)
        parts = rel.split("/")
        if parts[-1] != "kernel.py" or "kernels" not in parts[:-1]:
            continue
        directory = "/".join(parts[:-1])

        kernel_fns = _public_functions(ctx.tree)
        ref_tree = _parse_sibling(project, directory, "ref.py", by_path)
        if ref_tree is None:
            yield ctx.diag(1, "RL501",
                           f"`{directory}/` has no ref.py oracle for "
                           "kernel.py (bitwise-harness contract)")
        elif kernel_fns:
            ref_params = [param_names(fn)
                          for fn in _public_functions(ref_tree)]
            matched = any(
                _ordered_subset(rp, param_names(kfn))
                for kfn in kernel_fns for rp in ref_params)
            if not matched:
                yield ctx.diag(
                    kernel_fns[0], "RL502",
                    f"no public function in `{directory}/ref.py` "
                    "mirrors the kernel entry point's parameters "
                    "(ordered-subset match failed)")

        ops_tree = _parse_sibling(project, directory, "ops.py", by_path)
        if ops_tree is None:
            yield ctx.diag(1, "RL503",
                           f"`{directory}/` has no ops.py jit wrapper "
                           "(interpret-path contract)")
        elif not any("interpret" in param_names(fn)
                     for fn in _public_functions(ops_tree)):
            yield ctx.diag(1, "RL503",
                           f"no public function in `{directory}/ops.py`"
                           " takes `interpret`; CPU CI cannot exercise "
                           "the kernel path")
