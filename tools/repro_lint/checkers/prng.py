"""RL301 — PRNG key discipline: a jax.random key is consumed once.

The bug class PR 5 fixed in the refill traces: drawing twice from the
same key (or reusing a key after `split`) silently correlates streams
that the math assumes independent.  The checker runs a linear
must-consume analysis per function (and over module-level code):

* a variable becomes a *tracked key* when assigned from
  `jax.random.PRNGKey/key/split/fold_in` (tuple unpacking and
  subscripts of `split` results included), or when it is a parameter
  named `key`/`prng_key`/`*_key`;
* a *consumption* is passing it as the first argument to any
  `jax.random.*` sampler, or to `split` (reusing a key after splitting
  it is exactly the classic bug); `fold_in` derives a new stream and
  does not consume;
* consuming a key that this path already consumed — including a second
  pass over loop bodies for keys consumed once per iteration — fires
  RL301.  `if`/`else` branches merge must-consume (both branches), so
  exclusive-path use never false-positives.

Reassignment (including the `key, sub = jax.random.split(key)` idiom,
where the value is analyzed before the targets rebind) resets tracking.
Passing a key to a non-`jax.random` helper is not consumption: the
helper owns its own discipline.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from .. import registry
from ..pyast import dotted, resolve

registry.rule(
    "RL301", "prng-key-reuse",
    "a jax.random key must be consumed at most once; split/fold_in "
    "before drawing again (correlated-stream bug class of PR 5)")

_PRODUCERS = {"jax.random.PRNGKey", "jax.random.key", "jax.random.split",
              "jax.random.fold_in", "jax.random.wrap_key_data",
              "jax.random.clone"}
_NON_CONSUMING = {"PRNGKey", "key", "fold_in", "wrap_key_data", "clone",
                  "key_data", "key_impl"}
_KEY_PARAM_NAMES = ("key", "prng_key")


def _is_key_param(name: str) -> bool:
    return name in _KEY_PARAM_NAMES or name.endswith("_key")


class _FunctionScan:
    def __init__(self, ctx):
        self.ctx = ctx
        self.findings: Set[Tuple[str, int]] = set()

    # -- expression side ---------------------------------------------------

    def _producer_call(self, node: ast.AST) -> bool:
        """Is `node` a call (or subscript of a call) whose result is a
        fresh jax.random key?"""
        if isinstance(node, ast.Subscript):
            node = node.value
        if isinstance(node, ast.Call):
            q = resolve(dotted(node.func), self.ctx.aliases)
            return q in _PRODUCERS
        return False

    def _scan_expr(self, node: ast.AST, state: Dict[str, bool]):
        """Record key consumptions inside an expression."""
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            q = resolve(dotted(call.func), self.ctx.aliases)
            if q is None or not q.startswith("jax.random."):
                continue
            fn = q.rsplit(".", 1)[1]
            if fn in _NON_CONSUMING:
                continue
            if call.args and isinstance(call.args[0], ast.Name):
                var = call.args[0].id
                if var in state:
                    if state[var]:
                        self.findings.add((var, call.lineno))
                    state[var] = True

    # -- statement side ----------------------------------------------------

    def _bind_targets(self, targets, value, state: Dict[str, bool]):
        fresh = self._producer_call(value)
        for target in targets:
            if isinstance(target, ast.Name):
                if fresh:
                    state[target.id] = False
                else:
                    state.pop(target.id, None)
            elif isinstance(target, (ast.Tuple, ast.List)):
                for elt in target.elts:
                    if isinstance(elt, ast.Name):
                        if fresh:
                            state[elt.id] = False
                        else:
                            state.pop(elt.id, None)

    def scan_body(self, stmts: List[ast.stmt], state: Dict[str, bool]):
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue  # analyzed in their own scope
            if isinstance(stmt, ast.Assign):
                self._scan_expr(stmt.value, state)
                self._bind_targets(stmt.targets, stmt.value, state)
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                self._scan_expr(stmt.value, state)
                self._bind_targets([stmt.target], stmt.value, state)
            elif isinstance(stmt, ast.If):
                self._scan_expr(stmt.test, state)
                body_state = dict(state)
                else_state = dict(state)
                self.scan_body(stmt.body, body_state)
                self.scan_body(stmt.orelse, else_state)
                for var in state:
                    state[var] = (body_state.get(var, False)
                                  and else_state.get(var, False))
            elif isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    self._scan_expr(stmt.iter, state)
                    self._bind_targets([stmt.target], stmt.iter, state)
                else:
                    self._scan_expr(stmt.test, state)
                # two passes: a key consumed once per iteration is a
                # reuse from the second iteration on
                self.scan_body(stmt.body, state)
                self.scan_body(stmt.body, state)
                self.scan_body(stmt.orelse, state)
            elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                for item in stmt.items:
                    self._scan_expr(item.context_expr, state)
                self.scan_body(stmt.body, state)
            elif isinstance(stmt, ast.Try):
                self.scan_body(stmt.body, state)
                for handler in stmt.handlers:
                    self.scan_body(handler.body, dict(state))
                self.scan_body(stmt.orelse, state)
                self.scan_body(stmt.finalbody, state)
            else:
                for value in ast.iter_child_nodes(stmt):
                    if isinstance(value, ast.expr):
                        self._scan_expr(value, state)


@registry.file_checker
def check_prng(ctx):
    scans: List[Tuple[_FunctionScan, Dict[str, bool]]] = []

    # module-level straight-line code (the fixture corpus shape)
    mod_scan = _FunctionScan(ctx)
    mod_scan.scan_body(ctx.tree.body, {})
    scans.append(mod_scan)

    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        scan = _FunctionScan(ctx)
        state = {name: False for name in _iter_params(node)
                 if _is_key_param(name)}
        scan.scan_body(node.body, state)
        scans.append(scan)

    for scan, *_ in ((s,) for s in scans):
        for var, line in sorted(scan.findings, key=lambda f: f[1]):
            yield ctx.diag(line, "RL301",
                           f"jax.random key `{var}` consumed again "
                           "without an intervening split/fold_in "
                           "(correlated streams)")


def _iter_params(fndef):
    a = fndef.args
    for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
        yield p.arg
