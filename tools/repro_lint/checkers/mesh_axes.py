"""RL601 — mesh-axis naming.

`sharding/axes.py` is the single source of truth for mesh- and
logical-axis names (`CONFIG_AXIS`/`TRIAL_AXIS`, the `SWEEP_RULES`
table, the model-mesh rule sets).  A `PartitionSpec("confg")` typo
elsewhere compiles fine and silently replicates instead of sharding —
the worst kind of perf bug.  This checker collects every axis-name
string literal used in `PartitionSpec(...)`, `Mesh`/`make_mesh` axis
tuples and `axis_name=`/`axis_names=` keywords, and requires it to
appear in axes.py's declared-name table (axes.py itself is exempt — it
is the declaration site).
"""
from __future__ import annotations

import ast

from .. import registry
from ..pyast import dotted, resolve, string_args
from ..scopes import is_axes_module

registry.rule(
    "RL601", "unknown-mesh-axis",
    "PartitionSpec/Mesh/shard_map axis-name literals must be declared "
    "in sharding/axes.py (SWEEP_RULES/axis constants); a typo'd axis "
    "silently replicates instead of sharding")

_SPEC_CALLS = ("PartitionSpec",)
_MESH_CALLS = ("Mesh", "make_mesh")
_AXIS_KWARGS = {"axis_name"}
_AXIS_TUPLE_KWARGS = {"axis_names"}


def _literal_axis_names(call: ast.Call, aliases):
    """Yield (lineno, axis-name literal) used by this call, if it is an
    axis-naming construct."""
    q = resolve(dotted(call.func), aliases) or ""
    base = q.rsplit(".", 1)[-1]
    if base in _SPEC_CALLS:
        yield from string_args(call)
    elif base in _MESH_CALLS and len(call.args) >= 2:
        arg = call.args[1]
        if isinstance(arg, (ast.Tuple, ast.List)):
            for elt in arg.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    yield elt.lineno, elt.value
        elif isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            yield arg.lineno, arg.value
    for kw in call.keywords:
        if kw.arg in _AXIS_KWARGS and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            yield kw.value.lineno, kw.value.value
        elif kw.arg in _AXIS_TUPLE_KWARGS \
                and isinstance(kw.value, (ast.Tuple, ast.List)):
            for elt in kw.value.elts:
                if isinstance(elt, ast.Constant) \
                        and isinstance(elt.value, str):
                    yield elt.lineno, elt.value


@registry.project_checker
def check_mesh_axes(project):
    allowed = project.allowed_mesh_axes()
    if allowed is None:       # no axes.py in reach: contract unknowable
        return
    shown = ", ".join(sorted(allowed))
    for ctx in project.contexts:
        if is_axes_module(ctx.scope_path):
            continue
        for call in ast.walk(ctx.tree):
            if not isinstance(call, ast.Call):
                continue
            for lineno, name in _literal_axis_names(call, ctx.aliases):
                if name not in allowed:
                    yield ctx.diag(
                        lineno, "RL601",
                        f"axis name {name!r} is not declared in "
                        f"sharding/axes.py (known axes: {shown})")
