"""RL2xx — determinism: no wall clocks or ambient randomness in the
deterministic core.

Bitwise-identical resume (`core/resilience.py`), sweep ≡ sharded_sweep
equivalence, and the phase-salted trace seeding all assume that nothing
under `src/repro/core/`, `src/repro/sharding/` or `src/repro/kernels/`
reads a clock or an unseeded/global RNG.  `runtime/`, `serve/`,
`launch/`, tools, benchmarks and tests may do both (they time things and
generate smoke inputs) and are out of scope.
"""
from __future__ import annotations

import ast

from .. import registry
from ..pyast import resolve_call
from ..scopes import in_deterministic_core

registry.rule(
    "RL201", "wall-clock-in-core",
    "no time.time()/monotonic()/datetime.now() in the deterministic "
    "core: wall-clock values in outputs or control flow break "
    "bitwise-identical resume")
registry.rule(
    "RL202", "unseeded-numpy-rng",
    "np.random.default_rng()/RandomState() must be seeded and the "
    "global np.random.* samplers are banned in the deterministic core: "
    "trace generation must be a pure function of (seed, phase)")
registry.rule(
    "RL203", "stdlib-random-in-core",
    "the stdlib `random` module is process-global state; deterministic "
    "core code draws from seeded np.random.default_rng or jax.random "
    "keys instead")

_WALLCLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
    "datetime.datetime.now", "datetime.datetime.utcnow",
    "datetime.date.today",
}

# global-state numpy samplers (module-level np.random.*, not Generator
# methods); seeding the global state is just as order-dependent, so
# np.random.seed is included
_NUMPY_GLOBAL = {
    "seed", "rand", "randn", "randint", "random", "random_sample",
    "ranf", "sample", "normal", "uniform", "choice", "shuffle",
    "permutation", "standard_normal", "poisson", "exponential", "beta",
    "gamma", "binomial", "bytes", "get_state", "set_state",
}


def _is_seeded(call: ast.Call) -> bool:
    if call.args and not (isinstance(call.args[0], ast.Constant)
                          and call.args[0].value is None):
        return True
    return any(kw.arg == "seed" and not (
        isinstance(kw.value, ast.Constant) and kw.value.value is None)
        for kw in call.keywords)


@registry.file_checker
def check_determinism(ctx):
    if not in_deterministic_core(ctx.scope_path):
        return
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        q = resolve_call(node, ctx.aliases)
        if q is None:
            continue
        if q in _WALLCLOCK:
            yield ctx.diag(node, "RL201",
                           f"wall-clock call `{q}()` in deterministic "
                           "core (breaks bitwise resume/sweep "
                           "equivalence)")
        elif q in ("numpy.random.default_rng", "numpy.random.RandomState"):
            if not _is_seeded(node):
                yield ctx.diag(node, "RL202",
                               f"unseeded `{q}()` in deterministic core;"
                               " pass an explicit seed derived from the "
                               "config's (seed, phase)")
        elif q.startswith("numpy.random.") \
                and q.rsplit(".", 1)[1] in _NUMPY_GLOBAL:
            yield ctx.diag(node, "RL202",
                           f"global-state `{q}()` in deterministic "
                           "core; use a seeded np.random.default_rng "
                           "generator instead")
        elif q.startswith("random."):
            yield ctx.diag(node, "RL203",
                           f"stdlib `{q}()` in deterministic core; use "
                           "a seeded np.random.default_rng or a "
                           "jax.random key")
