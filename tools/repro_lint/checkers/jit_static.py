"""RL1xx — jit-static hygiene.

The sweep engines lean hard on `static_argnames` for jit-cache reuse
across bucketed grids: statics must be hashable Python values, and
anything *not* declared static is a tracer inside the function.  Two
checks:

* **RL101** — at repo call sites of a jitted function, a
  `static_argnames` argument must not be passed an expression that is
  array-typed by construction (a `jax.numpy.*` call, `jax.device_put`,
  …).  A traced static either crashes at trace time (unhashable) or,
  worse, retriggers compilation per value and defeats the bucketed
  jit cache.
* **RL102** — inside a directly-jitted function, Python `if`/`while` on
  a parameter that is not declared static branches on a tracer.
  Trace-safe predicates are exempt: `x is (not) None` (pytree-structure
  dispatch), `isinstance(...)`, `len(...)`, and attribute access like
  `x.shape`/`x.dtype`/`x.ndim` (static on tracers).

Both checks resolve `static_argnames` through module-level constants and
tuple concatenation (`_MC_STATICS + ("mesh",)`); when the static set
cannot be resolved the function is skipped rather than guessed at.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set

from .. import registry
from ..pyast import (dotted, fold_ints, fold_strings, param_names,
                     resolve)

registry.rule(
    "RL101", "traced-static-arg",
    "arguments declared in static_argnames must be hashable Python "
    "values at call sites, never jnp arrays/tracers (jit-cache "
    "bucketing contract)")
registry.rule(
    "RL102", "python-branch-on-traced-param",
    "Python if/while on a non-static parameter of a jitted function "
    "branches on a tracer; declare it static or use lax.cond/jnp.where")

_JIT_NAMES = {"jax.jit", "jax.api.jit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_TRACED_VALUE_PREFIXES = ("jax.numpy.",)
_TRACED_VALUE_CALLS = {"jax.device_put", "jax.numpy.asarray"}
_STATIC_ATTRS = {"shape", "dtype", "ndim", "size", "sharding"}
_EXEMPT_CALLS = {"isinstance", "len", "getattr", "hasattr", "type"}


def jit_statics(fndef, aliases, consts) -> Optional[Set[str]]:
    """If `fndef` is directly jitted, return its static parameter-name
    set; None if it is not jitted OR the statics cannot be resolved
    statically (callers must then stand down)."""
    params = param_names(fndef)
    for dec in fndef.decorator_list:
        target, kwargs = _jit_decorator(dec, aliases)
        if target is None:
            continue
        statics: Set[str] = set()
        for kw in kwargs:
            if kw.arg == "static_argnames":
                names = fold_strings(kw.value, consts)
                if names is None:
                    return None
                statics.update(names)
            elif kw.arg == "static_argnums":
                nums = fold_ints(kw.value)
                if nums is None:
                    return None
                for i in nums:
                    if 0 <= i < len(params):
                        statics.add(params[i])
        return statics
    return None


def _jit_decorator(dec, aliases):
    """-> (jit target, list of keywords) when `dec` is @jax.jit,
    @jax.jit(...), or @functools.partial(jax.jit, ...)."""
    if resolve(dotted(dec), aliases) in _JIT_NAMES:
        return dec, []
    if isinstance(dec, ast.Call):
        q = resolve(dotted(dec.func), aliases)
        if q in _JIT_NAMES:
            return dec.func, dec.keywords
        if q in _PARTIAL_NAMES and dec.args \
                and resolve(dotted(dec.args[0]), aliases) in _JIT_NAMES:
            return dec.args[0], dec.keywords
    return None, []


# ---------------------------------------------------------------------------
# RL102 — Python branch on a traced parameter (file checker)
# ---------------------------------------------------------------------------

def _offending_names(test: ast.AST, traced: Set[str]) -> List[ast.Name]:
    """Traced-parameter Name loads in a test expression, minus
    trace-safe contexts."""
    exempt_ids = set()
    for node in ast.walk(test):
        if isinstance(node, ast.Compare) \
                and all(isinstance(op, (ast.Is, ast.IsNot))
                        for op in node.ops) \
                and all(isinstance(c, ast.Constant) and c.value is None
                        for c in node.comparators):
            exempt_ids.update(id(n) for n in ast.walk(node))
        elif isinstance(node, ast.Attribute) \
                and node.attr in _STATIC_ATTRS:
            exempt_ids.update(id(n) for n in ast.walk(node))
        elif isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Name) and fn.id in _EXEMPT_CALLS:
                exempt_ids.update(id(n) for n in ast.walk(node))
    return [node for node in ast.walk(test)
            if isinstance(node, ast.Name) and node.id in traced
            and isinstance(node.ctx, ast.Load)
            and id(node) not in exempt_ids]


@registry.file_checker
def check_jit_branches(ctx):
    for fndef in ast.walk(ctx.tree):
        if not isinstance(fndef, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        statics = jit_statics(fndef, ctx.aliases, ctx.consts)
        if statics is None:
            continue
        traced = set(param_names(fndef)) - statics
        for node in _walk_own_body(fndef):
            if isinstance(node, (ast.If, ast.While)):
                for name in _offending_names(node.test, traced):
                    yield ctx.diag(
                        name, "RL102",
                        f"Python {'if' if isinstance(node, ast.If) else 'while'}"
                        f" on traced parameter `{name.id}` of jitted "
                        f"`{fndef.name}`; declare it in static_argnames "
                        "or use lax.cond/jnp.where")


def _walk_own_body(fndef):
    """Walk a function body without descending into nested defs (their
    parameters shadow; they get their own analysis if jitted)."""
    stack = list(fndef.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


# ---------------------------------------------------------------------------
# RL101 — traced value passed to a static arg (project checker:
# the jitted function and the call site may live in different modules)
# ---------------------------------------------------------------------------

def _is_traced_expr(node: ast.AST, aliases) -> bool:
    if not isinstance(node, ast.Call):
        return False
    q = resolve(dotted(node.func), aliases)
    if q is None:
        return False
    return q in _TRACED_VALUE_CALLS \
        or q.startswith(_TRACED_VALUE_PREFIXES)


@registry.project_checker
def check_static_call_sites(project):
    # pass 1: name -> static names, over every scanned module
    statics_by_name: Dict[str, Set[str]] = {}
    for ctx in project.contexts:
        for fndef in ast.walk(ctx.tree):
            if not isinstance(fndef, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            statics = jit_statics(fndef, ctx.aliases, ctx.consts)
            if statics:
                statics_by_name.setdefault(fndef.name, set()) \
                    .update(statics)
    if not statics_by_name:
        return
    # pass 2: call sites anywhere in the scanned set
    for ctx in project.contexts:
        for call in ast.walk(ctx.tree):
            if not isinstance(call, ast.Call):
                continue
            name = dotted(call.func)
            if name is None:
                continue
            statics = statics_by_name.get(name.rsplit(".", 1)[-1])
            if not statics:
                continue
            for kw in call.keywords:
                if kw.arg in statics \
                        and _is_traced_expr(kw.value, ctx.aliases):
                    yield ctx.diag(
                        kw.value, "RL101",
                        f"static argument `{kw.arg}` of jitted "
                        f"`{name}` is passed a traced-array "
                        "expression; statics must be hashable Python "
                        "values")
