"""repro-lint: trace-safety & determinism static analysis for this repo.

The framework's headline guarantees — bitwise-identical resume,
sweep ≡ sharded_sweep equivalence, jit-cache reuse across bucketed
grids — rest on source-level contracts (hashable statics, seeded RNG,
the float32 kernel contract, kernel/ref parity, declared mesh-axis
names).  This package turns those contracts into machine-checked
invariants that run before any compile or sweep does:

    python -m tools.repro_lint src tests tools \
        [--baseline .repro-lint-baseline.json] [--write-baseline FILE] \
        [--format text|json] [--include-fixtures] [--list-rules]

Rule catalog and suppression guidance: docs/static-analysis.md.
stdlib-only (`ast`) — no new dependencies.
"""
from .baseline import apply as apply_baseline  # noqa: F401
from .baseline import load as load_baseline    # noqa: F401
from .baseline import write as write_baseline  # noqa: F401
from .diagnostics import Diagnostic            # noqa: F401
from .engine import lint_paths, lint_source    # noqa: F401
from .registry import RULES                    # noqa: F401

__all__ = ["Diagnostic", "RULES", "lint_paths", "lint_source",
           "load_baseline", "write_baseline", "apply_baseline"]
