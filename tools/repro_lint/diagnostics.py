"""Diagnostics and inline-directive parsing for repro-lint.

A diagnostic is (path, line, code, message); `path` is always a
posix-style path relative to the repo root so baselines are portable
across checkouts.

Inline directives live in comments:

    x = foo()  # repro-lint: disable=RL201
    # repro-lint: disable-next-line=RL201,RL301
    # repro-lint: path=src/repro/core/fixture.py   (first 10 lines only)

`disable=` suppresses the listed codes on its own line,
`disable-next-line=` on the following line.  `path=` overrides the
*scope* path used for path-scoped rules (determinism, dtype) without
changing the reported path — it exists so the lint fixture corpus under
`tests/fixtures/lint/` can exercise rules whose scope is
`src/repro/core/` etc.; production code has no reason to use it.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Set, Tuple

_DIRECTIVE = re.compile(r"#\s*repro-lint:\s*(?P<body>[^\n]*)")

# how many leading lines may carry a `path=` scope override
_PATH_DIRECTIVE_WINDOW = 10


@dataclasses.dataclass(frozen=True, order=True)
class Diagnostic:
    path: str
    line: int
    code: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"

    def to_json(self) -> dict:
        return {"path": self.path, "line": self.line,
                "code": self.code, "message": self.message}


def parse_directives(lines: List[str]
                     ) -> Tuple[Dict[int, Set[str]], Optional[str]]:
    """Scan raw source lines for repro-lint comment directives.

    Returns `(suppressions, scope_path)` where `suppressions` maps a
    1-based line number to the set of RL codes disabled on that line,
    and `scope_path` is the `path=` override (or None).
    """
    suppressions: Dict[int, Set[str]] = {}
    scope_path: Optional[str] = None
    for lineno, text in enumerate(lines, start=1):
        m = _DIRECTIVE.search(text)
        if m is None:
            continue
        for token in m.group("body").split():
            if "=" not in token:
                continue
            key, _, value = token.partition("=")
            codes = {c for c in value.split(",") if c}
            if key == "disable":
                suppressions.setdefault(lineno, set()).update(codes)
            elif key == "disable-next-line":
                suppressions.setdefault(lineno + 1, set()).update(codes)
            elif (key == "path" and scope_path is None
                  and lineno <= _PATH_DIRECTIVE_WINDOW):
                scope_path = value
    return suppressions, scope_path
