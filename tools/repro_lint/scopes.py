"""Path scopes: which repo regions each contract applies to.

Scopes are decided on the file's *scope path* — normally its repo-root-
relative path, overridable by a `# repro-lint: path=` directive in the
lint fixture corpus (see `diagnostics`).
"""
from __future__ import annotations

# Deterministic core: everything a sweep/resume/jit-cache bitwise
# guarantee flows through.  Wall clocks, unseeded RNGs and stdlib
# `random` are banned here; `runtime/`, `serve/`, `launch/`, tools and
# benchmarks may time and randomize freely.
DETERMINISTIC_PREFIXES = (
    "src/repro/core/",
    "src/repro/sharding/",
    "src/repro/kernels/",
)

# Kernel-reachable modules: the float32 kernel contract
# (`kernels/placement_score/ops.py` rejects float64 inputs) extends to
# every module whose arrays can flow into a kernel call.
KERNEL_REACHABLE_CORE = {
    "placement.py", "singlehall.py", "fleet.py", "sweep.py",
    "mc_sweep.py", "quantiles.py",
}

AXES_MODULE = "src/repro/sharding/axes.py"


def norm(path) -> str:
    return str(path).replace("\\", "/")


def in_deterministic_core(path) -> bool:
    return norm(path).startswith(DETERMINISTIC_PREFIXES)


def in_kernel_reachable(path) -> bool:
    p = norm(path)
    if p.startswith("src/repro/kernels/"):
        return True
    return (p.startswith("src/repro/core/")
            and p.rsplit("/", 1)[-1] in KERNEL_REACHABLE_CORE)


def is_axes_module(path) -> bool:
    return norm(path) == AXES_MODULE
