"""Baseline files: grandfathered findings, matched by (path, code) count.

The baseline maps `"<path>::<code>"` to the number of findings that are
tolerated there.  Matching by count (not line numbers) keeps the
baseline stable under unrelated edits; it also makes the shrink-only
policy checkable — `tests/test_repro_lint.py` asserts the committed
baseline's total and that no entry is stale, so a PR can remove
baseline debt but never silently add to it.

A group that *exceeds* its budget reports every finding in the group:
line-level attribution of "which one is new" is not decidable from
counts, and showing the whole group is what lets the author pick which
to fix.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Tuple

from .diagnostics import Diagnostic

VERSION = 1


def group_key(d: Diagnostic) -> str:
    return f"{d.path}::{d.code}"


def counts_of(diags: Iterable[Diagnostic]) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for d in diags:
        counts[group_key(d)] = counts.get(group_key(d), 0) + 1
    return counts


def load(path) -> Dict[str, int]:
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("version") != VERSION:
        raise ValueError(f"{path}: not a repro-lint baseline "
                         f"(want version {VERSION})")
    counts = data.get("counts", {})
    if not all(isinstance(k, str) and isinstance(v, int) and v > 0
               for k, v in counts.items()):
        raise ValueError(f"{path}: malformed baseline counts")
    return dict(counts)


def write(path, diags: Iterable[Diagnostic]) -> Dict[str, int]:
    counts = counts_of(diags)
    data = {
        "version": VERSION,
        "total": sum(counts.values()),
        "counts": {k: counts[k] for k in sorted(counts)},
    }
    text = json.dumps(data, indent=2, sort_keys=False) + "\n"
    pathlib.Path(path).write_text(text, encoding="utf-8")
    return counts


def apply(diags: List[Diagnostic], counts: Dict[str, int]
          ) -> Tuple[List[Diagnostic], Dict[str, int]]:
    """Split findings against a baseline.

    Returns `(new, stale)`: `new` is every finding not covered by the
    baseline budgets (a group over budget reports whole); `stale` maps
    baseline keys whose budget exceeds the current finding count to the
    unused surplus — debt that was paid down and should be removed from
    the baseline file.
    """
    groups: Dict[str, List[Diagnostic]] = {}
    for d in diags:
        groups.setdefault(group_key(d), []).append(d)
    new: List[Diagnostic] = []
    for key, group in groups.items():
        if len(group) > counts.get(key, 0):
            new.extend(group)
    stale = {key: budget - len(groups.get(key, ()))
             for key, budget in counts.items()
             if budget > len(groups.get(key, ()))}
    return sorted(new), stale
