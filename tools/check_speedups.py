"""Assert benchmark speedup ratios from a --json dump are >= a floor.

Usage:  python tools/check_speedups.py BENCH_mc.json [BENCH_sweep.json ...]

Scans every row whose name contains "speedup" for a `<key>=<ratio>x`
pair in its derived field and fails (exit 1) if any ratio is below the
floor (default 1.0 — batched/split paths must never be slower than the
sequential/legacy reference; override with --min).  The gated families
today: `sweep.speedup`, `mc.speedup`, `pod_sweep.speedup` and
`mc_pod.speedup` — any future `*speedup*` row is gated automatically.
A row may carry its own floor as a `min=<floor>` token in its derived
field (e.g. `resilience.overhead_speedup` gates at 0.9: checkpointing
is allowed ≤10% overhead, not required to be a speedup); the per-row
floor overrides the global one.  Rows whose derived field says
`skipped=` (e.g. the sharded probe on a 1-device host) are ignored.
At least one ratio must be found, so an empty or mis-filtered dump
also fails.

Exit codes distinguish the failure class so CI logs are unambiguous:
0 = all gates pass, 1 = a gate failed (ratio below floor, malformed
row, or no ratios found), 2 = a dump file is missing or unreadable.
Every failing row is printed with its full derived field.
"""
from __future__ import annotations

import argparse
import json
import re
import sys

EXIT_OK = 0
EXIT_GATE_FAILED = 1
EXIT_FILE_ERROR = 2


def check(paths, floor: float) -> int:
    found, failed = 0, []
    for path in paths:
        try:
            with open(path) as f:
                rows = json.load(f)
        except OSError as e:
            print(f"FAIL {path}: cannot read dump ({e})", file=sys.stderr)
            return EXIT_FILE_ERROR
        except json.JSONDecodeError as e:
            print(f"FAIL {path}: not valid JSON ({e})", file=sys.stderr)
            return EXIT_FILE_ERROR
        if not isinstance(rows, dict):
            print(f"FAIL {path}: expected a JSON object of rows, got "
                  f"{type(rows).__name__}", file=sys.stderr)
            return EXIT_FILE_ERROR
        for name, row in sorted(rows.items()):
            if "speedup" not in name:
                continue
            derived = row.get("derived", "")
            if "skipped=" in derived:
                print(f"{name}: skipped ({derived})")
                continue
            m = re.search(r"=([0-9.]+)x", derived)
            if not m:
                failed.append(f"{name}: no '<ratio>x' in derived field "
                              f"{derived!r}")
                continue
            found += 1
            ratio = float(m.group(1))
            m_floor = re.search(r"(?:^|;)min=([0-9.]+)", derived)
            row_floor = float(m_floor.group(1)) if m_floor else floor
            ok = ratio >= row_floor
            print(f"{name}: {ratio:.2f}x "
                  f"({'ok' if ok else f'BELOW floor {row_floor}'})")
            if not ok:
                failed.append(f"{name}: {ratio:.2f}x < floor {row_floor} "
                              f"(derived: {derived!r})")
    if not found:
        failed.append("no speedup ratios found in "
                      + ", ".join(paths))
    for msg in failed:
        print(f"FAIL {msg}", file=sys.stderr)
    return EXIT_GATE_FAILED if failed else EXIT_OK


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="+", help="--json dumps to check")
    ap.add_argument("--min", type=float, default=1.0,
                    help="minimum acceptable speedup ratio (default 1.0)")
    args = ap.parse_args(argv)
    return check(args.json, args.min)


if __name__ == "__main__":
    sys.exit(main())
