"""Assert benchmark speedup ratios from a --json dump are >= a floor.

Usage:  python tools/check_speedups.py BENCH_mc.json [BENCH_sweep.json ...]

Scans every row whose name contains "speedup" for a `<key>=<ratio>x`
pair in its derived field and fails (exit 1) if any ratio is below the
floor (default 1.0 — batched/split paths must never be slower than the
sequential/legacy reference; override with --min).  The gated families
today: `sweep.speedup`, `mc.speedup`, `pod_sweep.speedup` and
`mc_pod.speedup` — any future `*speedup*` row is gated automatically.
A row may carry its own floor as a `min=<floor>` token in its derived
field (e.g. `resilience.overhead_speedup` gates at 0.9: checkpointing
is allowed ≤10% overhead, not required to be a speedup); the per-row
floor overrides the global one.  Rows whose derived field says
`skipped=` (e.g. the sharded probe on a 1-device host) are ignored.
At least one ratio must be found, so an empty or mis-filtered dump
also fails.
"""
from __future__ import annotations

import argparse
import json
import re
import sys


def check(paths, floor: float) -> int:
    found, failed = 0, []
    for path in paths:
        with open(path) as f:
            rows = json.load(f)
        for name, row in sorted(rows.items()):
            if "speedup" not in name:
                continue
            derived = row.get("derived", "")
            if "skipped=" in derived:
                print(f"{name}: skipped ({derived})")
                continue
            m = re.search(r"=([0-9.]+)x", derived)
            if not m:
                failed.append(f"{name}: no '<ratio>x' in {derived!r}")
                continue
            found += 1
            ratio = float(m.group(1))
            m_floor = re.search(r"(?:^|;)min=([0-9.]+)", derived)
            row_floor = float(m_floor.group(1)) if m_floor else floor
            ok = ratio >= row_floor
            print(f"{name}: {ratio:.2f}x "
                  f"({'ok' if ok else f'BELOW floor {row_floor}'})")
            if not ok:
                failed.append(f"{name}: {ratio:.2f}x < {row_floor}")
    if not found:
        failed.append("no speedup ratios found in "
                      + ", ".join(paths))
    for msg in failed:
        print(f"FAIL {msg}", file=sys.stderr)
    return 1 if failed else 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("json", nargs="+", help="--json dumps to check")
    ap.add_argument("--min", type=float, default=1.0,
                    help="minimum acceptable speedup ratio (default 1.0)")
    args = ap.parse_args(argv)
    return check(args.json, args.min)


if __name__ == "__main__":
    sys.exit(main())
