"""Repo tooling: CI gates (check_speedups, check_links) and the
repro-lint static-analysis pass (`python -m tools.repro_lint`)."""
