#!/usr/bin/env python
"""Markdown link check: every relative link target in the repo's *.md
files must exist on disk.  External http(s)/mailto links are not fetched
(CI has no network guarantees); pure-anchor links are skipped.

    python tools/check_links.py [repo_root]

Exit status: 0 = no broken links, 1 = broken links found (each is
printed as `file: broken link -> target`), 2 = the given root does
not exist or is not a directory.  Also importable:
`check(root) -> list[str]` returns the broken-link report lines
(used by tests/test_docs.py).
"""
from __future__ import annotations

import pathlib
import re
import sys

EXIT_OK = 0
EXIT_BROKEN = 1
EXIT_BAD_ROOT = 2

# [text](target) — target up to the first unescaped ')' or whitespace.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_DIRS = {".git", "node_modules", "__pycache__", ".venv"}
_EXTERNAL = ("http://", "https://", "mailto:")


def check(root: pathlib.Path) -> list[str]:
    root = root.resolve()
    errors = []
    for md in sorted(root.rglob("*.md")):
        if _SKIP_DIRS.intersection(md.relative_to(root).parts):
            continue
        for target in _LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(_EXTERNAL):
                continue
            path = target.split("#", 1)[0]
            if not path:                      # pure in-page anchor
                continue
            if not (md.parent / path).resolve().exists():
                errors.append(
                    f"{md.relative_to(root)}: broken link -> {target}")
    return errors


def main(argv: list[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parents[1]
    if not root.is_dir():
        print(f"FAIL {root}: not a directory", file=sys.stderr)
        return EXIT_BAD_ROOT
    errors = check(root)
    for e in errors:
        print(e)
    n_md = len(list(root.rglob("*.md")))
    print(f"# checked {n_md} markdown files, {len(errors)} broken link(s)")
    return EXIT_BROKEN if errors else EXIT_OK


if __name__ == "__main__":
    sys.exit(main(sys.argv))
