"""Multi-resource demand vectors (paper §4.1, Appendix C.1).

Each deployment unit r carries a demand vector
    d_r = (P_r [kW], CFM_r [air], LPM_r [liquid], n_r [tiles])
Cooling demand is derived from rack power with the paper's fixed
conversions: 165 CFM/kW for air cooling and 2 LPM per rack for
direct-to-chip liquid cooling (OCP guideline, paper §4.1).

GPU racks split cooling: the accelerator share is liquid-cooled, while
networking/overhead (``GPU_AIR_FRACTION`` of rack power) remains
air-cooled.  General-compute and storage racks have LPM_r = 0.
"""
from __future__ import annotations

import jax.numpy as jnp

# Resource dimension indices (paper §4.3: m ∈ {power, air, liquid, space}).
POWER, AIR, LIQ, TILES = 0, 1, 2, 3
N_RES = 4
RESOURCE_NAMES = ("power_kw", "air_cfm", "liquid_lpm", "tiles")

# Fixed conversions (paper §4.1, [OCP'23]).
AIR_CFM_PER_KW = 165.0
LIQ_LPM_PER_RACK = 2.0
# Fraction of a GPU rack's power that is air-cooled (networking, misc).
GPU_AIR_FRACTION = 0.10

# Hardware classes (paper §5.1).
CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE = 0, 1, 2
CLASS_NAMES = ("gpu", "compute", "storage")

# Availability tiers (paper §4.1).
TIER_HA, TIER_LA = 0, 1


def rack_demand(rack_kw, is_gpu):
    """Per-rack demand vector d_r = (kW, CFM, LPM, tiles).

    Works on scalars or arrays (broadcasts); returns shape (..., 4).
    """
    rack_kw = jnp.asarray(rack_kw, jnp.float32)
    is_gpu = jnp.asarray(is_gpu)
    air_frac = jnp.where(is_gpu, GPU_AIR_FRACTION, 1.0)
    air = AIR_CFM_PER_KW * rack_kw * air_frac
    liq = jnp.where(is_gpu, LIQ_LPM_PER_RACK, 0.0)
    tiles = jnp.ones_like(rack_kw)
    return jnp.stack([rack_kw, air, liq, tiles], axis=-1)
