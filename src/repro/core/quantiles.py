"""Streaming quantile estimators for scan-carried statistics.

The lifecycle scan's p50/p90 mature-hall stranding used to be a
post-hoc reduction over the scanned ``[M, H]`` stranding history — fine
for one configuration, a memory ceiling for 10⁵–10⁶-config grids.  This
module provides the O(1)-memory alternatives `fleet.simulate_lifecycle`
compiles when ``exact_quantiles=False``:

* `hist_masked_quantiles` — fixed-bin histogram quantiles over a masked
  cross-section.  Stranding fractions live in a known range (``[0, 1]``),
  so a static ``n_bins``-bucket histogram plus rank interpolation
  estimates any quantile with absolute error ≤ one bin width
  ``(hi - lo) / n_bins`` (each interpolated order statistic is located
  within its true bucket; see `_rank_value`).  This is what the scan
  body calls per month: it consumes the ``[H]`` cross-section in place
  and emits two scalars, so no ``[M, H]`` history is ever materialized.

* `p2_stream_quantiles` — the classic Jain & Chlamtac P² estimator,
  vectorized over quantiles and scanned over a masked stream.  Five
  markers per quantile track (min, p/2-ish, p-ish, (1+p)/2-ish, max)
  order statistics with parabolic updates; streams shorter than five
  valid observations fall back to the exact small-sample quantile.  P²
  carries no hard error bound (it is exact-bucket-free), so it is the
  right tool for *unbounded-range* streams; the property-test harness
  (`tests/test_streaming_quantiles.py`) drives it against
  ``np.percentile`` with a tolerance that shrinks as the stream grows.

Both estimators use ``np.percentile``'s 'linear' rank convention
(``pos = q/100 · (n-1)``) so the exact and streaming paths agree as
``n_bins → ∞`` / ``n → ∞``.  All-masked-out inputs yield NaN — the same
sentinel `fleet._masked_percentiles` emits for an all-False mask.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Default histogram resolution for the streaming scan path: 512 buckets
# over [0, 1] bounds the stranding-quantile error at ~0.2% absolute,
# well inside the tolerance of every consumer (the goldens assert 2e-3).
DEFAULT_BINS = 512


def _rank_value(counts, cdf, j, n_bins, lo, width):
    """Histogram estimate of the value at integer 0-indexed rank ``j``.

    Bucket ``k`` holds ranks ``[cdf[k-1], cdf[k])``, so the true order
    statistic lies in ``[lo + k·width, lo + (k+1)·width)``; spreading the
    bucket's mass uniformly places rank ``j`` at fraction
    ``(j - cdf[k-1] + 0.5) / counts[k]`` through the bucket.  The
    estimate therefore never leaves the true bucket → error ≤ ``width``.
    """
    k = jnp.clip(jnp.searchsorted(cdf, j, side="right"), 0, n_bins - 1)
    below = jnp.where(k > 0, cdf[jnp.maximum(k - 1, 0)], 0.0)
    frac = jnp.clip((j - below + 0.5) / jnp.maximum(counts[k], 1.0),
                    0.0, 1.0)
    return lo + width * (k.astype(jnp.float32) + frac)


def hist_masked_quantiles(x, mask, qs, n_bins: int = DEFAULT_BINS,
                          lo: float = 0.0, hi: float = 1.0):
    """Histogram quantiles of ``x[mask]`` for each static q in ``qs``.

    Values are clipped into ``[lo, hi]`` before binning (the documented
    error bound holds only for in-range data; stranding fractions are).
    The continuous rank ``q/100 · (n-1)`` is linearly interpolated
    between its two neighboring integer-rank estimates, each located
    within its true bucket, so the absolute error is at most one bin
    width ``(hi - lo) / n_bins``.  Returns a tuple of scalars, NaN when
    the mask selects nothing — the same interface and sentinel as
    `fleet._masked_percentiles`.
    """
    width = (hi - lo) / n_bins
    w = jnp.clip((x - lo) / (hi - lo), 0.0, 1.0)
    b = jnp.minimum((w * n_bins).astype(jnp.int32), n_bins - 1)
    counts = jnp.zeros((n_bins,), jnp.float32).at[b].add(
        mask.astype(jnp.float32))
    cdf = jnp.cumsum(counts)
    n = cdf[-1]
    top = jnp.maximum(n - 1.0, 0.0)
    out = []
    for q in qs:
        pos = q / 100.0 * top
        j_lo = jnp.floor(pos)
        frac = pos - j_lo
        v_lo = _rank_value(counts, cdf, j_lo, n_bins, lo, width)
        v_hi = _rank_value(counts, cdf, jnp.ceil(pos), n_bins, lo, width)
        val = v_lo * (1.0 - frac) + v_hi * frac
        out.append(jnp.where(n > 0, val, jnp.nan))
    return tuple(out)


def _small_sample_quantiles(buf, n, qs):
    """Exact 'linear' quantiles of the first ``n`` (< 5) entries of the
    sorted, +inf-padded 5-slot P² bootstrap buffer."""
    top = jnp.maximum(n.astype(jnp.float32) - 1.0, 0.0)
    out = []
    for q in qs:
        pos = q / 100.0 * top
        k_lo = jnp.floor(pos).astype(jnp.int32)
        k_hi = jnp.ceil(pos).astype(jnp.int32)
        frac = pos - k_lo.astype(jnp.float32)
        out.append(buf[k_lo] * (1.0 - frac) + buf[k_hi] * frac)
    return jnp.stack(out)


def p2_stream_quantiles(xs, mask, qs):
    """P² streaming quantiles of the masked stream ``xs[mask]``.

    ``xs``/``mask`` are ``[N]``; ``qs`` is a static tuple of percentiles
    (e.g. ``(50.0, 90.0)``).  Returns a ``[len(qs)]`` array.  Each
    quantile keeps the classic five markers (heights ``q``, integer
    positions ``pos``, desired positions ``1 + (n-1)·d``) updated with
    the parabolic P² rule and its linear fallback; the first five valid
    observations bootstrap the markers from the exact sorted sample, and
    streams that never reach five fall back to the exact small-sample
    quantile (NaN when the mask selects nothing).

    The whole estimator is one ``lax.scan`` with an O(len(qs)) carry —
    the memory shape a scan-carried statistic must have.
    """
    Q = len(qs)
    qarr = jnp.asarray([q / 100.0 for q in qs], jnp.float32)   # [Q]
    # desired-position increments d = [0, p/2, p, (1+p)/2, 1]     [Q, 5]
    d = jnp.stack([jnp.zeros_like(qarr), qarr / 2.0, qarr,
                   (1.0 + qarr) / 2.0, jnp.ones_like(qarr)], axis=1)

    def p2_update(h, pos, n, x):
        """One P² step for all Q marker sets at once ([Q, 5] arrays)."""
        # cell index k ∈ [1, 4]: number of markers ≤ x, with the end
        # markers stretched to min/max first
        h = h.at[:, 0].min(x).at[:, 4].max(x)
        k = jnp.clip(jnp.sum(x >= h, axis=1), 1, 4)             # [Q]
        pos = pos + (jnp.arange(5)[None, :] >= k[:, None])
        n_des = 1.0 + (n - 1.0) * d                             # [Q, 5]
        # middle markers adjust sequentially (marker i sees i-1's move)
        for i in (1, 2, 3):
            hm, hi, hp = h[:, i - 1], h[:, i], h[:, i + 1]
            pm, pi, pp = pos[:, i - 1], pos[:, i], pos[:, i + 1]
            delta = n_des[:, i] - pi
            s = jnp.where((delta >= 1.0) & (pp - pi > 1.0), 1.0,
                          jnp.where((delta <= -1.0) & (pm - pi < -1.0),
                                    -1.0, 0.0))
            # parabolic estimate; linear fallback keeps monotonicity
            para = hi + s / (pp - pm) * (
                (pi - pm + s) * (hp - hi) / (pp - pi)
                + (pp - pi - s) * (hi - hm) / (pi - pm))
            lin = hi + s * jnp.where(s > 0, (hp - hi) / (pp - pi),
                                     (hi - hm) / (pi - pm))
            new = jnp.where((para <= hm) | (para >= hp), lin, para)
            h = h.at[:, i].set(jnp.where(s != 0.0, new, hi))
            pos = pos.at[:, i].set(pi + s)
        return h, pos

    pos0 = jnp.broadcast_to(jnp.arange(1.0, 6.0), (Q, 5))

    def step(carry, inp):
        h, pos, n = carry
        x, ok = inp
        # bootstrap phase (n < 5): insert x into the sorted +inf-padded
        # 5-slot buffer shared by every marker row; the step that fills
        # slot 5 leaves exactly the sorted initial markers with
        # positions [1..5].  The P² branch is computed unconditionally
        # (its inf-poisoned bootstrap result is discarded by the where).
        boot = jnp.sort(
            h.at[:, jnp.minimum(n, 4.0).astype(jnp.int32)].set(x), axis=1)
        h_u, pos_u = p2_update(h, pos, n + 1.0, x)
        use_boot = n < 5.0
        h_n = jnp.where(ok, jnp.where(use_boot, boot, h_u), h)
        pos_n = jnp.where(ok, jnp.where(use_boot, pos0, pos_u), pos)
        n_n = jnp.where(ok, n + 1.0, n)
        return (h_n, pos_n, n_n), None

    h0 = jnp.full((Q, 5), jnp.inf, jnp.float32)
    (h, pos, n), _ = jax.lax.scan(
        step, (h0, pos0, jnp.zeros((), jnp.float32)),
        (jnp.asarray(xs, jnp.float32), jnp.asarray(mask, bool)))

    small = _small_sample_quantiles(h[0], n, qs)   # rows identical for n<5
    est = jnp.where(n >= 5.0, h[:, 2], small)
    return jnp.where(n > 0.0, est, jnp.nan)
