"""Power-delivery hierarchy: designs, line-ups, rows, wiring (paper §2, App. C).

A hall is a tree  substation → UPS line-ups → rows → racks.  We model the
levels that bind placement: line-ups (UPS domains) and rows, plus hall-level
liquid-cooling capacity.  Two redundancy families (paper §2.3):

* distributed ``xN/y``: all x line-ups are active; each may carry HA load up
  to (y/x)·C (Eq. 27) and must retain failover headroom Δ = P_r/(k_r−1)
  (Eq. 1) for every HA deployment it feeds.
* block ``N+k``: y = N primary line-ups carry load to full rating C; k
  standby line-ups exist only for failover (they cost money but admit no
  load), so usable capacity is quantized per line-up (Eq. 2).

Row wiring follows Appendix C.2: low-density rows connect to 2 upstream
line-ups, high-density rows to 4 (distributed) — balanced across the
admissible combinations within a power domain; block-design rows draw from a
single primary line-up (the reserve path is via STS and consumes no primary
capacity).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace
from typing import Tuple

import numpy as np
import jax.numpy as jnp

from .resources import (AIR, AIR_CFM_PER_KW, LIQ, LIQ_LPM_PER_RACK, N_RES,
                        POWER, TILES)

MAX_FEEDS = 4


class SweepValidationError(ValueError):
    """A sweep input failed validation before any compile time was spent.

    `field` names the offending spec field (e.g. ``"lineup_kw"`` or
    ``"envs"``); `message` is the human-readable diagnosis.  Subclasses
    ValueError so pre-existing ``pytest.raises(ValueError)`` call sites
    keep working.
    """

    def __init__(self, field: str, message: str):
        self.field = field
        self.message = message
        super().__init__(f"{field}: {message}")


def _require(ok: bool, field: str, message: str) -> None:
    if not ok:
        raise SweepValidationError(field, message)


@dataclass(frozen=True)
class DesignSpec:
    """A power-delivery reference design (paper Table 1 / App. C.2)."""
    name: str
    kind: str                    # 'distributed' | 'block'
    n_lineups: int               # x: total UPS line-ups (incl. reserve)
    n_active: int                # y: line-ups of supported HA load
    lineup_kw: float = 2500.0    # 2.5 MW UPS line-up (Table 1)
    n_domains: int = 1           # power domains partitioning the line-ups
    ld_rows: int = 18
    hd_rows: int = 12
    ld_row_kw: float = 625.0     # Table 1 electrical granularity
    hd_row_kw: float = 2500.0
    ld_feeds: int = 2            # App. C.2 row classes
    hd_feeds: int = 4
    tiles_per_row: int = 24      # App. C.2
    # Cooling provisioning (see DESIGN.md §4 — supply sizing is ours):
    air_provision_ratio: float = 1.0
    liq_gpu_share: float = 0.7        # design-point GPU share of HA power
    liq_ref_rack_kw: float = 150.0    # design-point GPU rack density

    @property
    def ha_capacity_kw(self) -> float:
        # distributed: (y/x)·x·C = y·C ; block: y primaries · C  → identical.
        return self.n_active * self.lineup_kw

    @property
    def ha_frac(self) -> float:
        """Effective HA fraction of a line-up's rating (Eq. 27)."""
        if self.kind == "distributed":
            return self.n_active / self.n_lineups
        return 1.0

    @property
    def n_rows(self) -> int:
        return self.ld_rows + self.hd_rows

    @property
    def hall_liq_cap_lpm(self) -> float:
        """Liquid plant sized for `liq_gpu_share` of HA power at the
        reference GPU rack density (2 LPM per rack)."""
        ref_racks = self.liq_gpu_share * self.ha_capacity_kw / self.liq_ref_rack_kw
        return ref_racks * LIQ_LPM_PER_RACK

    def validate(self) -> "DesignSpec":
        """Raise `SweepValidationError` on an unbuildable design."""
        d = self
        _require(d.kind in ("distributed", "block"), "kind",
                 f"unknown design kind {d.kind!r}; expected 'distributed' "
                 f"or 'block'")
        _require(d.n_lineups >= 1, "n_lineups",
                 f"design {d.name!r} needs at least one line-up, got "
                 f"{d.n_lineups}")
        _require(1 <= d.n_active <= d.n_lineups, "n_active",
                 f"design {d.name!r} has n_active={d.n_active} outside "
                 f"[1, n_lineups={d.n_lineups}]")
        _require(d.lineup_kw > 0, "lineup_kw",
                 f"design {d.name!r} has non-positive line-up rating "
                 f"{d.lineup_kw} kW")
        _require(d.n_domains >= 1, "n_domains",
                 f"design {d.name!r} needs at least one power domain, got "
                 f"{d.n_domains}")
        _require(d.ld_rows >= 0 and d.hd_rows >= 0, "ld_rows",
                 f"design {d.name!r} has negative row counts "
                 f"(ld_rows={d.ld_rows}, hd_rows={d.hd_rows})")
        _require(d.n_rows > 0, "ld_rows",
                 f"design {d.name!r} has zero rows (ld_rows + hd_rows == 0); "
                 f"nothing can ever place")
        _require(d.ld_row_kw > 0 and d.hd_row_kw > 0, "ld_row_kw",
                 f"design {d.name!r} has non-positive row power caps "
                 f"(ld_row_kw={d.ld_row_kw}, hd_row_kw={d.hd_row_kw})")
        _require(d.ld_feeds >= 1 and d.hd_feeds >= 1, "ld_feeds",
                 f"design {d.name!r} has a zero-feed row class "
                 f"(ld_feeds={d.ld_feeds}, hd_feeds={d.hd_feeds}); every "
                 f"row needs at least one upstream line-up")
        _require(max(d.ld_feeds, d.hd_feeds) <= MAX_FEEDS, "hd_feeds",
                 f"design {d.name!r} requests more than MAX_FEEDS="
                 f"{MAX_FEEDS} feeds per row")
        _require(d.tiles_per_row > 0, "tiles_per_row",
                 f"design {d.name!r} has non-positive tiles_per_row "
                 f"{d.tiles_per_row}")
        _require(d.air_provision_ratio >= 0, "air_provision_ratio",
                 f"design {d.name!r} has negative air_provision_ratio "
                 f"{d.air_provision_ratio}")
        _require(0.0 <= d.liq_gpu_share <= 1.0, "liq_gpu_share",
                 f"design {d.name!r} has liq_gpu_share {d.liq_gpu_share} "
                 f"outside [0, 1]")
        _require(d.liq_ref_rack_kw > 0, "liq_ref_rack_kw",
                 f"design {d.name!r} has non-positive liq_ref_rack_kw "
                 f"{d.liq_ref_rack_kw}")
        return d


def _balanced_combos(n: int, r: int, count: int, offset: int = 0):
    """Cyclically assign `count` rows over all C(n, r) feed combinations."""
    combos = list(itertools.combinations(range(n), r))
    return [tuple(offset + c for c in combos[i % len(combos)])
            for i in range(count)]


@dataclass(frozen=True)
class HallTopology:
    """Static (numpy) arrays describing one hall design, possibly tiled over
    H halls with globally-indexed rows/line-ups (fleet mode)."""
    design: DesignSpec
    n_halls: int
    row_cap: np.ndarray        # [R_tot, N_RES] float32
    row_feeds: np.ndarray      # [R_tot, MAX_FEEDS] int32, -1 padded
    row_nfeeds: np.ndarray     # [R_tot] int32
    row_is_hd: np.ndarray      # [R_tot] bool
    row_domain: np.ndarray     # [R_tot] int32 (global domain id)
    row_hall: np.ndarray       # [R_tot] int32
    lineup_cap: np.ndarray     # [X_tot] float32 (kW rating C)
    lineup_is_active: np.ndarray  # [X_tot] bool (block reserve = False)
    lineup_hall: np.ndarray    # [X_tot] int32 — hall owning each line-up
    hall_liq_cap: np.ndarray   # [H] float32
    ha_frac: float
    is_block: bool

    @property
    def rows_per_hall(self) -> int:
        # derived from the arrays (≥ design.n_rows when padded for sweeps)
        return self.row_cap.shape[0] // self.n_halls

    @property
    def lineups_per_hall(self) -> int:
        return self.lineup_cap.shape[0] // self.n_halls

    @property
    def n_hd_rows(self) -> int:
        """HD-row count across all halls (the compacted pod-scan length)."""
        return int(np.asarray(self.row_is_hd).sum())

    def ha_capacity_kw(self) -> float:
        return self.design.ha_capacity_kw * self.n_halls

    def validate(self) -> "HallTopology":
        """Raise `SweepValidationError` on an internally inconsistent
        topology (hand-built grids bypassing `build_topology`)."""
        t = self
        _require(t.n_halls >= 1, "n_halls",
                 f"topology needs at least one hall, got {t.n_halls}")
        R_tot = t.row_cap.shape[0]
        X_tot = t.lineup_cap.shape[0]
        _require(R_tot > 0, "row_cap",
                 "topology has zero rows; nothing can ever place")
        _require(X_tot > 0, "lineup_cap",
                 "topology has zero line-ups; no power can be delivered")
        _require(R_tot % t.n_halls == 0, "row_cap",
                 f"{R_tot} rows do not tile evenly over {t.n_halls} halls")
        _require(X_tot % t.n_halls == 0, "lineup_cap",
                 f"{X_tot} line-ups do not tile evenly over "
                 f"{t.n_halls} halls")
        for name, arr, n in (("row_feeds", t.row_feeds, R_tot),
                             ("row_nfeeds", t.row_nfeeds, R_tot),
                             ("row_is_hd", t.row_is_hd, R_tot),
                             ("row_domain", t.row_domain, R_tot),
                             ("row_hall", t.row_hall, R_tot),
                             ("lineup_is_active", t.lineup_is_active, X_tot),
                             ("lineup_hall", t.lineup_hall, X_tot)):
            _require(arr.shape[0] == n, name,
                     f"{name} has {arr.shape[0]} entries, expected {n}")
        _require(t.row_feeds.shape[1] == MAX_FEEDS, "row_feeds",
                 f"row_feeds second axis is {t.row_feeds.shape[1]}, "
                 f"expected MAX_FEEDS={MAX_FEEDS}")
        _require(t.hall_liq_cap.shape[0] == t.n_halls, "hall_liq_cap",
                 f"hall_liq_cap has {t.hall_liq_cap.shape[0]} entries, "
                 f"expected n_halls={t.n_halls}")
        feeds = np.asarray(t.row_feeds)
        _require(bool(np.all((feeds >= -1) & (feeds < X_tot))), "row_feeds",
                 f"row_feeds references line-ups outside [-1, {X_tot})")
        # Real rows (positive power capacity) must be wired to a line-up;
        # zero-capacity padding rows may legitimately have no feeds.
        real = np.asarray(t.row_cap)[:, POWER] > 0
        unfed = real & (np.asarray(t.row_nfeeds) <= 0)
        _require(not bool(unfed.any()), "row_nfeeds",
                 f"{int(unfed.sum())} powered row(s) have zero feeds "
                 f"(first at index {int(np.argmax(unfed))}); every powered "
                 f"row needs at least one upstream line-up")
        caps = np.asarray(t.lineup_cap)
        _require(bool(np.all(caps >= 0)), "lineup_cap",
                 "negative line-up power caps")
        active = np.asarray(t.lineup_is_active)
        dead = active & (caps <= 0)
        _require(not bool(dead.any()), "lineup_cap",
                 f"{int(dead.sum())} active line-up(s) have non-positive "
                 f"power caps (first at index {int(np.argmax(dead))})")
        _require(bool(active.any()), "lineup_is_active",
                 "no active line-ups; no load can ever be admitted")
        _require(0.0 < t.ha_frac <= 1.0, "ha_frac",
                 f"ha_frac {t.ha_frac} outside (0, 1]")
        return t


def build_topology(design: DesignSpec, n_halls: int = 1,
                   rows_per_hall: int | None = None,
                   lineups_per_hall: int | None = None) -> HallTopology:
    """Build the (possibly multi-hall) topology for `design`.

    `rows_per_hall` / `lineups_per_hall` optionally pad every hall to a
    common static shape so heterogeneous designs can be stacked and
    `vmap`-ed together (sweep engine): padding rows have zero capacity and
    no feeds (never feasible), padding line-ups are inactive with zero
    rating (contribute nothing to stranding metrics).
    """
    d = design.validate()        # zero-row / zero-feed / bad caps → precise error
    _require(n_halls >= 1, "n_halls",
             f"need at least one hall, got {n_halls}")
    if d.kind == "distributed":
        active = list(range(d.n_lineups))
        per_dom = d.n_lineups // d.n_domains
    else:
        active = list(range(d.n_active))       # primaries first
        per_dom = d.n_active // d.n_domains
    if per_dom * d.n_domains != len(active):
        raise SweepValidationError(
            "n_domains", f"design {d.name!r}: line-ups must partition "
            f"evenly into {d.n_domains} domains")
    if d.ld_rows % d.n_domains or d.hd_rows % d.n_domains:
        raise SweepValidationError(
            "n_domains", f"design {d.name!r}: rows must partition evenly "
            f"into {d.n_domains} domains")

    ld_per_dom = d.ld_rows // d.n_domains
    hd_per_dom = d.hd_rows // d.n_domains

    feeds, nfeeds, is_hd, domain = [], [], [], []
    for dom in range(d.n_domains):
        off = dom * per_dom
        if d.kind == "distributed":
            ld = _balanced_combos(per_dom, min(d.ld_feeds, per_dom), ld_per_dom, off)
            hd = _balanced_combos(per_dom, min(d.hd_feeds, per_dom), hd_per_dom, off)
        else:
            # block: one primary feed per row, round-robin within domain.
            ld = [(off + i % per_dom,) for i in range(ld_per_dom)]
            hd = [(off + i % per_dom,) for i in range(hd_per_dom)]
        for combo in ld:
            feeds.append(combo); nfeeds.append(len(combo))
            is_hd.append(False); domain.append(dom)
        for combo in hd:
            feeds.append(combo); nfeeds.append(len(combo))
            is_hd.append(True); domain.append(dom)

    R = len(feeds)
    row_feeds = np.full((R, MAX_FEEDS), -1, np.int32)
    for i, combo in enumerate(feeds):
        row_feeds[i, :len(combo)] = combo
    row_nfeeds = np.asarray(nfeeds, np.int32)
    row_is_hd = np.asarray(is_hd, bool)
    row_domain = np.asarray(domain, np.int32)

    row_kw = np.where(row_is_hd, d.hd_row_kw, d.ld_row_kw).astype(np.float32)
    row_cap = np.zeros((R, N_RES), np.float32)
    row_cap[:, POWER] = row_kw
    row_cap[:, AIR] = d.air_provision_ratio * AIR_CFM_PER_KW * row_kw
    row_cap[:, LIQ] = np.where(row_is_hd, 1e9, 0.0)   # liquid loops only in HD rows;
    row_cap[:, TILES] = d.tiles_per_row               # the binding cap is hall-level.

    lineup_cap = np.full((d.n_lineups,), d.lineup_kw, np.float32)
    lineup_is_active = np.zeros((d.n_lineups,), bool)
    lineup_is_active[active] = True

    # --- pad the single hall to a requested common shape (sweep batching) ---
    R_pad = rows_per_hall or R
    X_pad = lineups_per_hall or d.n_lineups
    if R_pad < R or X_pad < d.n_lineups:
        raise ValueError(
            f"padding ({R_pad} rows, {X_pad} line-ups) smaller than design "
            f"({R} rows, {d.n_lineups} line-ups)")
    if R_pad > R:
        pad = R_pad - R
        row_cap = np.concatenate([row_cap, np.zeros((pad, N_RES), np.float32)])
        row_feeds = np.concatenate(
            [row_feeds, np.full((pad, MAX_FEEDS), -1, np.int32)])
        row_nfeeds = np.concatenate([row_nfeeds, np.zeros((pad,), np.int32)])
        row_is_hd = np.concatenate([row_is_hd, np.zeros((pad,), bool)])
        row_domain = np.concatenate([row_domain, np.zeros((pad,), np.int32)])
        R = R_pad
    if X_pad > d.n_lineups:
        pad = X_pad - d.n_lineups
        lineup_cap = np.concatenate([lineup_cap, np.zeros((pad,), np.float32)])
        lineup_is_active = np.concatenate(
            [lineup_is_active, np.zeros((pad,), bool)])

    # --- tile over H halls with global indices ---
    H = n_halls
    X = X_pad
    row_feeds_g = np.concatenate(
        [np.where(row_feeds >= 0, row_feeds + h * X, -1) for h in range(H)], 0)
    tile = lambda a: np.concatenate([a] * H, 0)
    topo = HallTopology(
        design=d, n_halls=H,
        row_cap=tile(row_cap),
        row_feeds=row_feeds_g.astype(np.int32),
        row_nfeeds=tile(row_nfeeds),
        row_is_hd=tile(row_is_hd),
        row_domain=np.concatenate(
            [row_domain + h * d.n_domains for h in range(H)], 0).astype(np.int32),
        row_hall=np.concatenate(
            [np.full((R,), h, np.int32) for h in range(H)], 0),
        lineup_cap=np.concatenate([lineup_cap] * H, 0),
        lineup_is_active=np.concatenate([lineup_is_active] * H, 0),
        lineup_hall=np.repeat(np.arange(H, dtype=np.int32), X),
        hall_liq_cap=np.full((H,), d.hall_liq_cap_lpm, np.float32),
        ha_frac=d.ha_frac,
        is_block=(d.kind == "block"),
    )
    return topo


# ---------------------------------------------------------------------------
# Reference designs (paper Table 1 / §3.1 / §6.1).
# ---------------------------------------------------------------------------

def design_4n3() -> DesignSpec:
    """4N/3 distributed-redundant, 7.5 MW HA (paper §3.1)."""
    return DesignSpec("4N/3", "distributed", n_lineups=4, n_active=3,
                      n_domains=1, ld_rows=18, hd_rows=12)


def design_3p1() -> DesignSpec:
    """3+1 block-redundant, 7.5 MW HA (paper §3.1). App. C.2 base hall:
    6N LD + 4N HD rows with N = 3 primaries."""
    return DesignSpec("3+1", "block", n_lineups=4, n_active=3,
                      n_domains=1, ld_rows=18, hd_rows=12)


def design_10n8() -> DesignSpec:
    """10N/8 distributed, 20 MW HA.  Two domains of 5 line-ups (see
    DESIGN.md §4 for the balanced-subset rationale): LD rows multiple of
    C(5,2)=10 per domain, HD rows multiple of C(5,4)=5 per domain, chosen
    to hit the 3:2 LD:HD reference ratio."""
    return DesignSpec("10N/8", "distributed", n_lineups=10, n_active=8,
                      n_domains=2, ld_rows=60, hd_rows=40)


def design_8p2() -> DesignSpec:
    """8+2 block-redundant, 20 MW HA.  App. C.2 base hall: 6N LD + 4N HD
    with N = 8 primaries."""
    return DesignSpec("8+2", "block", n_lineups=10, n_active=8,
                      n_domains=2, ld_rows=48, hd_rows=32)


DESIGNS = {
    "4N/3": design_4n3,
    "3+1": design_3p1,
    "10N/8": design_10n8,
    "8+2": design_8p2,
}


def get_design(name: str) -> DesignSpec:
    try:
        return DESIGNS[name]()
    except KeyError:
        raise KeyError(f"unknown design {name!r}; have {list(DESIGNS)}")
