"""Core reproduction of "Designing Datacenter Power Delivery Hierarchies
for the AI Era": hierarchy/redundancy modelling, multi-resource placement,
single-hall and fleet lifecycle simulation, cost and throughput models."""

from . import (arrivals, calibration, cost, fleet, hierarchy, mc_sweep,
               payoff, placement, projections, quantiles, resilience,
               resources, scenarios, singlehall, sweep, throughput)
from .hierarchy import (DESIGNS, DesignSpec, SweepValidationError,
                        build_topology, design_3p1, design_4n3, design_8p2,
                        design_10n8, get_design)
from .resilience import (FaultPlan, RunReport, resilient_mc_sweep,
                         resilient_sweep)
from .placement import (DEFAULT_POLICY, POLICY_MIN_WASTE, POLICY_NAMES,
                        POLICY_RANDOM, POLICY_ROUND_ROBIN, POLICY_VAR_MIN,
                        Deployment, HallState, place)
from .mc_sweep import MCAxes, MCResult
from .sweep import SweepAxes, SweepResult

__all__ = [
    "arrivals", "calibration", "cost", "fleet", "hierarchy", "mc_sweep",
    "payoff", "placement", "projections", "quantiles", "resilience",
    "resources", "scenarios", "singlehall", "sweep", "throughput",
    "DESIGNS", "DesignSpec", "SweepValidationError", "build_topology",
    "get_design", "design_4n3", "design_3p1", "design_10n8", "design_8p2",
    "Deployment", "HallState", "place", "DEFAULT_POLICY", "POLICY_NAMES",
    "POLICY_RANDOM", "POLICY_ROUND_ROBIN", "POLICY_MIN_WASTE",
    "POLICY_VAR_MIN", "SweepAxes", "SweepResult", "MCAxes", "MCResult",
    "FaultPlan", "RunReport", "resilient_sweep", "resilient_mc_sweep",
]
