"""Scenario generators beyond the paper grid (docs/scenarios.md).

The paper evaluates designs on one fixed arrival grid (TDP scenario ×
pod size × seed).  This module programmatically produces *families* of
`EnvelopeSpec` perturbations around any base envelope — demand shocks,
correlated-lifetime cohorts, workload-mix / LA-share sweeps, and
decommission-wave refresh cycles — so the planning objective
(*deployable capacity over time*) can be stressed under arrival,
oversubscription, and decommissioning sequences the paper never ran.

Each generator returns a named `ScenarioBatch` (aligned labels + envs)
that feeds directly into the batched sweep engine, so a whole family is
ONE compiled, device-sharded call:

    from repro.core import hierarchy, scenarios
    from repro.core.arrivals import EnvelopeSpec
    from repro.core.sweep import sharded_sweep

    batch = scenarios.demand_shocks(EnvelopeSpec(demand_scale=0.01))
    res = sharded_sweep(batch.axes([hierarchy.get_design("3+1")]))
    dict(zip(res.tags, res.p90_stranding[:, -1]))

The perturbation *semantics* live in `arrivals.py` (EnvelopeSpec
scenario knobs + trace post-processing), so every family flows through
the same `generate_fleet_trace` synthesis and the same lifecycle scan;
neutral knobs (multiplier 1.0 / window 0 / cycle 0 / `mix_end=None`)
reproduce the paper baseline bit-for-bit (`tests/test_scenarios.py`).
`payoff.scenario_frontier` runs baseline + all four families on one
grid and reports stranding / effective-capex deltas.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple

from .arrivals import EnvelopeSpec
from .placement import DEFAULT_POLICY
from .sweep import SweepAxes

FAMILY_SHOCK = "shock"
FAMILY_COHORT = "cohort"
FAMILY_MIX = "mix"
FAMILY_REFRESH = "refresh"
# The four arrival-perturbation families `scenario_frontier` runs by
# default.  FAMILY_POD is deliberately NOT in this tuple: pod quanta
# change the placement granularity (a design-frontier axis), not the
# arrival stream, so `pod_quanta` batches are opt-in.
FAMILIES = (FAMILY_SHOCK, FAMILY_COHORT, FAMILY_MIX, FAMILY_REFRESH)
FAMILY_POD = "pod"
BASELINE_TAG = "baseline:paper"


@dataclass(frozen=True)
class ScenarioBatch:
    """One scenario family: aligned (labels, envs) around a base envelope.

    `labels[i]` names perturbation `i` within the family (e.g. `m18_x1.5`
    for a 1.5× surge at month 18); `tags()` prefixes the family name so
    configurations stay identifiable after batches are concatenated into
    one sweep grid.
    """
    family: str
    labels: Tuple[str, ...]
    envs: Tuple[EnvelopeSpec, ...]

    def __post_init__(self):
        if len(self.labels) != len(self.envs):
            raise ValueError(
                f"{self.family}: {len(self.labels)} labels for "
                f"{len(self.envs)} envs")

    def __len__(self):
        return len(self.envs)

    def tags(self) -> Tuple[str, ...]:
        """`"family:label"` per perturbation (see `SweepAxes.tags`)."""
        return tuple(f"{self.family}:{lb}" for lb in self.labels)

    def axes(self, designs, policies=(DEFAULT_POLICY,),
             seeds: Sequence[int] = (0,)) -> SweepAxes:
        """Cross this family with designs/policies/seeds — sweep-ready.

        Returns a `SweepAxes` whose `tags` carry the family labels, so
        `sweep(batch.axes(...))` evaluates the whole family as one
        compiled call and `SweepResult.tags` identifies each row.
        """
        return SweepAxes.product(designs=list(designs), envs=list(self.envs),
                                 policies=policies, seeds=seeds,
                                 env_tags=list(self.tags()))


def demand_shocks(base: Optional[EnvelopeSpec] = None, *,
                  months: Sequence[int] = (18,),
                  multipliers: Sequence[float] = (0.5, 1.5),
                  ramp_months: Sequence[int] = (0, 6)) -> ScenarioBatch:
    """(a) Demand shocks: step/ramp multipliers on the monthly budgets.

    One perturbation per (shock month × multiplier × ramp): budgets
    before `month` are untouched; after it they scale by `multiplier`
    (>1 surge, <1 bust), stepped (`ramp 0`) or linearly ramped over
    `ramp` months.  Labels: `m{month}_x{multiplier}_{step|ramp<R>}`.
    """
    base = base if base is not None else EnvelopeSpec()
    labels, envs = [], []
    for m in months:
        for x in multipliers:
            for r in ramp_months:
                kind = "step" if r == 0 else f"ramp{r}"
                labels.append(f"m{m}_x{x:g}_{kind}")
                envs.append(replace(base, shock_month=int(m),
                                    shock_multiplier=float(x),
                                    shock_ramp_months=int(r)))
    return ScenarioBatch(FAMILY_SHOCK, tuple(labels), tuple(envs))


def correlated_cohorts(base: Optional[EnvelopeSpec] = None, *,
                       windows_m: Sequence[int] = (3, 6, 12)
                       ) -> ScenarioBatch:
    """(b) Correlated-lifetime cohorts: same-window arrivals decommission
    together.

    One perturbation per window width: all same-class deployments
    arriving within one `window`-month window share a decommission epoch
    (one lifetime draw per cohort) instead of drawing independent
    N(μ,σ) lifetimes — the capacity-return stream becomes bursty.
    Labels: `w{window}`.
    """
    base = base if base is not None else EnvelopeSpec()
    windows = tuple(int(w) for w in windows_m)
    return ScenarioBatch(
        FAMILY_COHORT,
        tuple(f"w{w}" for w in windows),
        tuple(replace(base, cohort_window_m=w) for w in windows))


def mix_sweeps(base: Optional[EnvelopeSpec] = None, *,
               gpu_share_end: Sequence[float] = (0.35, 0.8),
               la_fractions: Sequence[float] = (0.0, 0.3)) -> ScenarioBatch:
    """(c) Workload-mix / LA-share sweeps: continuous interpolation of the
    accelerator-vs-general-vs-storage power split per year.

    One perturbation per (end-of-horizon GPU share × LA fraction): the
    per-year class split interpolates linearly from the baseline split
    to `(g, 0.7·(1−g), 0.3·(1−g))` at `end_year` (total annual demand
    preserved), optionally with an LA-tier arrival share.  Labels:
    `gpu{share%}_la{fraction%}`.
    """
    base = base if base is not None else EnvelopeSpec()
    labels, envs = [], []
    for g in gpu_share_end:
        mix = (float(g), (1.0 - g) * 0.7, (1.0 - g) * 0.3)
        for la in la_fractions:
            labels.append(f"gpu{int(round(g * 100))}_la{int(round(la * 100))}")
            envs.append(replace(base, mix_end=mix, la_fraction=float(la)))
    return ScenarioBatch(FAMILY_MIX, tuple(labels), tuple(envs))


def refresh_waves(base: Optional[EnvelopeSpec] = None, *,
                  cycles_m: Sequence[int] = (12, 24, 36)) -> ScenarioBatch:
    """(d) Decommission-wave refresh cycles: hardware-generation turnover
    pulses.

    One perturbation per cycle length: every deployment's end-of-life
    month snaps up to the next multiple of the cycle, so decommissioning
    arrives in synchronized waves instead of a smooth stream.  Labels:
    `c{cycle}`.
    """
    base = base if base is not None else EnvelopeSpec()
    cycles = tuple(int(c) for c in cycles_m)
    return ScenarioBatch(
        FAMILY_REFRESH,
        tuple(f"c{c}" for c in cycles),
        tuple(replace(base, refresh_cycle_m=c) for c in cycles))


def pod_quanta(base: Optional[EnvelopeSpec] = None, *,
               pod_sizes: Sequence[int] = (1, 5)) -> ScenarioBatch:
    """Pod placement-quantum family: the §6.5 serving-vs-deployability
    axis (`payoff.design_frontier` consumes this).

    One perturbation per pod size: GPU arrivals come in `p`-rack pods
    (`p = 1` is the rack-scale baseline quantum; pod sizes > 1 switch to
    Kyber pod-scale racks).  Labels: `p{size}`.  Not part of `FAMILIES` /
    `all_families` — see the note on the tuple above.
    """
    base = base if base is not None else EnvelopeSpec()
    sizes = tuple(int(p) for p in pod_sizes)
    return ScenarioBatch(
        FAMILY_POD,
        tuple(f"p{p}" for p in sizes),
        tuple(replace(base, pod_racks=p, pod_scale_arch=p > 1 or
                      base.pod_scale_arch) for p in sizes))


def all_families(base: Optional[EnvelopeSpec] = None
                 ) -> Dict[str, ScenarioBatch]:
    """All four scenario families at their catalog defaults, keyed by
    family name (`FAMILIES` order)."""
    base = base if base is not None else EnvelopeSpec()
    batches = (demand_shocks(base), correlated_cohorts(base),
               mix_sweeps(base), refresh_waves(base))
    return {b.family: b for b in batches}


def frontier_axes(designs, base: Optional[EnvelopeSpec] = None,
                  seeds: Sequence[int] = (0,),
                  families: Optional[Dict[str, ScenarioBatch]] = None
                  ) -> SweepAxes:
    """Baseline + every family on ONE tagged sweep grid.

    Configuration 0 of each (design, seed) block is the unperturbed base
    envelope (tag `baseline:paper`), so per-scenario deltas are computed
    against a baseline simulated in the same compiled call
    (`payoff.scenario_frontier` consumes this).
    """
    base = base if base is not None else EnvelopeSpec()
    fams = all_families(base) if families is None else families
    envs, tags = [base], [BASELINE_TAG]
    for b in fams.values():
        envs.extend(b.envs)
        tags.extend(b.tags())
    return SweepAxes.product(designs=list(designs), envs=envs, seeds=seeds,
                             env_tags=tags)
