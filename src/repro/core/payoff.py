"""Pod payoff analysis (paper §6.5, Figs. 17–18) and the beyond-the-paper
scenario frontier.

Pod Payoff = (1 + ΔTPS/W) / (1 + ΔCost) − 1   relative to a single-rack
baseline, where ΔTPS/W is the serving-side gain from pod-local EP
communication and ΔCost is the lifecycle deployability penalty of the
coarser placement quantum (from fleet simulation).

`scenario_frontier` stresses one design across every scenario family in
`repro.core.scenarios` (demand shocks, correlated cohorts, mix/LA
sweeps, refresh waves) on ONE sweep grid and reports p50/p90 stranding
and effective-capex deltas against the paper baseline simulated in the
same compiled call (docs/scenarios.md).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence

import numpy as np

from . import fleet, projections as proj, scenarios as sc, throughput as tp
from .arrivals import EnvelopeSpec
from .hierarchy import DesignSpec
from .sweep import SweepAxes, sharded_sweep, sweep


@dataclass
class PayoffPoint:
    design: str
    model: str
    pod_racks: int
    tps_per_watt: float
    d_tps_per_watt: float
    effective_dpm: float
    d_cost: float
    payoff: float
    fleet_tps_per_watt: float = 0.0


def serving_gain(model: tp.MoEModel, pod_racks: int, year: int = 2028,
                 scenario: str = proj.HIGH) -> tuple[float, float]:
    """(TPS/W, ΔTPS/W vs single rack) for Kyber-era deployments."""
    base = tp.Deployment(proj.KYBER, year, 1, scenario)
    pod = tp.Deployment(proj.KYBER, year, pod_racks, scenario)
    t0 = tp.tps_per_watt(model, base)
    t1 = tp.tps_per_watt(model, pod)
    return t1, (t1 - t0) / t0


def pod_payoff_study(design: DesignSpec, models: Sequence[tp.MoEModel],
                     pod_sizes: Sequence[int] = (1, 3, 5, 7),
                     env: EnvelopeSpec | None = None, seed: int = 0,
                     year: int = 2028,
                     fleet_cache: Dict[int, fleet.FleetResult] | None = None,
                     ) -> list[PayoffPoint]:
    """Fleet-cost side is model-independent (the hierarchy sees only the
    placement quantum), so fleet sims are run once per pod size and reused
    across models — all missing pod sizes are evaluated in ONE batched
    sweep call (device-sharded when more than one device is visible).
    `fleet_cache` may be shared across designs' calls."""
    env = env or EnvelopeSpec(demand_scale=0.05, gpu_scenario=proj.HIGH,
                              pod_scale_arch=True)
    results: Dict[int, fleet.FleetResult] = fleet_cache if fleet_cache is not None else {}
    missing = [n for n in pod_sizes if n not in results]
    if missing:
        axes = SweepAxes.zip(designs=[design],
                             envs=[replace(env, pod_racks=n)
                                   for n in missing],
                             seeds=[seed])
        res = sharded_sweep(axes)
        for i, n in enumerate(missing):
            results[n] = res.result(i)

    base_cost = results[pod_sizes[0]].effective_dpm
    points = []
    for m in models:
        for n in pod_sizes:
            tw, d_tps = serving_gain(m, n, year)
            d_cost = results[n].effective_dpm / base_cost - 1.0
            payoff = (1 + d_tps) / (1 + d_cost) - 1.0
            # fleet-level TPS/W: deployed GPU MW × per-watt serving rate
            r = results[n]
            gpu_share = env.gpu_gw / (env.gpu_gw + env.compute_gw + env.storage_gw)
            fleet_tps = tw * r.final_deployed_mw * 1e6 * gpu_share
            fleet_tpw = fleet_tps / (r.final_deployed_mw * 1e6)
            points.append(PayoffPoint(
                design.name, m.name, n, tw, d_tps, r.effective_dpm, d_cost,
                payoff, fleet_tpw))
    return points


@dataclass
class ScenarioPoint:
    """One (scenario, seed) row of the frontier study.

    Deltas are against the paper-baseline configuration with the same
    design and seed from the SAME sweep call (`d_* == 0` for the
    baseline rows themselves).
    """
    family: str             # "baseline" or a scenarios.FAMILIES name
    label: str              # perturbation label within the family
    seed: int
    p50_stranding: float    # final-month p50 over mature halls
    p90_stranding: float    # final-month p90 (the paper's tail metric)
    n_halls: int
    deployed_mw: float
    effective_dpm: float    # lifecycle-effective $/MW
    total_capex: float      # $
    d_p90: float            # p90 stranding delta vs baseline (absolute)
    d_capex: float          # fractional total-capex delta vs baseline
    d_dpm: float            # fractional effective-$/MW delta vs baseline


def scenario_frontier(design: DesignSpec,
                      base_env: Optional[EnvelopeSpec] = None,
                      seeds: Sequence[int] = (0,),
                      families: Optional[Dict[str, sc.ScenarioBatch]] = None,
                      sharded: bool = True) -> list[ScenarioPoint]:
    """Beyond-the-paper scenario study (docs/scenarios.md).

    Evaluates `design` on the paper baseline plus every scenario family
    (defaults: `scenarios.all_families(base_env)`) as ONE batched sweep
    call — device-sharded when `sharded` and more than one device is
    visible — and returns one `ScenarioPoint` per (scenario, seed) with
    stranding and effective-capex deltas against the same-seed baseline.

        pts = scenario_frontier(hierarchy.get_design("3+1"),
                                EnvelopeSpec(demand_scale=0.01))
        max(pts, key=lambda p: p.p90_stranding)     # worst-case envelope
    """
    base_env = base_env if base_env is not None else \
        EnvelopeSpec(demand_scale=0.01)
    axes = sc.frontier_axes([design], base=base_env, seeds=seeds,
                            families=families)
    res = (sharded_sweep if sharded else sweep)(axes)

    base_idx = {axes.seeds[i]: i for i in range(len(axes))
                if axes.tags[i] == sc.BASELINE_TAG}
    points = []
    for i in range(len(axes)):
        fam, label = axes.tags[i].split(":", 1)
        j = base_idx[axes.seeds[i]]
        points.append(ScenarioPoint(
            family=fam, label=label, seed=axes.seeds[i],
            p50_stranding=float(res.p50_stranding[i, -1]),
            p90_stranding=float(res.p90_stranding[i, -1]),
            n_halls=int(res.n_halls_built[i]),
            deployed_mw=float(res.final_deployed_mw[i]),
            effective_dpm=float(res.effective_dpm[i]),
            total_capex=float(res.total_capex[i]),
            d_p90=float(res.p90_stranding[i, -1] - res.p90_stranding[j, -1]),
            d_capex=float(res.total_capex[i] / max(res.total_capex[j], 1.0)
                          - 1.0),
            d_dpm=float(res.effective_dpm[i] / max(res.effective_dpm[j],
                                                   1e-9) - 1.0)))
    return points
