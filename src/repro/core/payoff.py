"""Pod payoff analysis (paper §6.5, Figs. 17–18).

Pod Payoff = (1 + ΔTPS/W) / (1 + ΔCost) − 1   relative to a single-rack
baseline, where ΔTPS/W is the serving-side gain from pod-local EP
communication and ΔCost is the lifecycle deployability penalty of the
coarser placement quantum (from fleet simulation).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Sequence

import numpy as np

from . import fleet, projections as proj, throughput as tp
from .arrivals import EnvelopeSpec
from .hierarchy import DesignSpec
from .sweep import SweepAxes, sharded_sweep


@dataclass
class PayoffPoint:
    design: str
    model: str
    pod_racks: int
    tps_per_watt: float
    d_tps_per_watt: float
    effective_dpm: float
    d_cost: float
    payoff: float
    fleet_tps_per_watt: float = 0.0


def serving_gain(model: tp.MoEModel, pod_racks: int, year: int = 2028,
                 scenario: str = proj.HIGH) -> tuple[float, float]:
    """(TPS/W, ΔTPS/W vs single rack) for Kyber-era deployments."""
    base = tp.Deployment(proj.KYBER, year, 1, scenario)
    pod = tp.Deployment(proj.KYBER, year, pod_racks, scenario)
    t0 = tp.tps_per_watt(model, base)
    t1 = tp.tps_per_watt(model, pod)
    return t1, (t1 - t0) / t0


def pod_payoff_study(design: DesignSpec, models: Sequence[tp.MoEModel],
                     pod_sizes: Sequence[int] = (1, 3, 5, 7),
                     env: EnvelopeSpec | None = None, seed: int = 0,
                     year: int = 2028,
                     fleet_cache: Dict[int, fleet.FleetResult] | None = None,
                     ) -> list[PayoffPoint]:
    """Fleet-cost side is model-independent (the hierarchy sees only the
    placement quantum), so fleet sims are run once per pod size and reused
    across models — all missing pod sizes are evaluated in ONE batched
    sweep call (device-sharded when more than one device is visible).
    `fleet_cache` may be shared across designs' calls."""
    env = env or EnvelopeSpec(demand_scale=0.05, gpu_scenario=proj.HIGH,
                              pod_scale_arch=True)
    results: Dict[int, fleet.FleetResult] = fleet_cache if fleet_cache is not None else {}
    missing = [n for n in pod_sizes if n not in results]
    if missing:
        axes = SweepAxes.zip(designs=[design],
                             envs=[replace(env, pod_racks=n)
                                   for n in missing],
                             seeds=[seed])
        res = sharded_sweep(axes)
        for i, n in enumerate(missing):
            results[n] = res.result(i)

    base_cost = results[pod_sizes[0]].effective_dpm
    points = []
    for m in models:
        for n in pod_sizes:
            tw, d_tps = serving_gain(m, n, year)
            d_cost = results[n].effective_dpm / base_cost - 1.0
            payoff = (1 + d_tps) / (1 + d_cost) - 1.0
            # fleet-level TPS/W: deployed GPU MW × per-watt serving rate
            r = results[n]
            gpu_share = env.gpu_gw / (env.gpu_gw + env.compute_gw + env.storage_gw)
            fleet_tps = tw * r.final_deployed_mw * 1e6 * gpu_share
            fleet_tpw = fleet_tps / (r.final_deployed_mw * 1e6)
            points.append(PayoffPoint(
                design.name, m.name, n, tw, d_tps, r.effective_dpm, d_cost,
                payoff, fleet_tpw))
    return points
