"""Pod payoff analysis (paper §6.5, Figs. 17–18) and the beyond-the-paper
scenario frontier.

Pod Payoff = (1 + ΔTPS/W) / (1 + ΔCost) − 1   relative to a single-rack
baseline, where ΔTPS/W is the serving-side gain from pod-local EP
communication and ΔCost is the lifecycle deployability penalty of the
coarser placement quantum (from fleet simulation).

`scenario_frontier` stresses one design across every scenario family in
`repro.core.scenarios` (demand shocks, correlated cohorts, mix/LA
sweeps, refresh waves) on ONE sweep grid and reports p50/p90 stranding,
effective-capex and delivered-TPS deltas against the paper baseline
simulated in the same compiled call (docs/scenarios.md).

`design_frontier` is the $/performance synthesis: every design × pod
quantum × seed evaluated on one sweep grid, priced against the Table 2
model suite by the sweep's metric stage, with Pareto-dominated
(delivered tokens/s vs. effective capex) points flagged per model
(docs/architecture.md, `examples/frontier_study.py`).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence

import numpy as np

from . import fleet, hierarchy, projections as proj, scenarios as sc
from . import throughput as tp
from .arrivals import EnvelopeSpec
from .hierarchy import DesignSpec
from .sweep import SweepAxes, gpu_power_share, sharded_sweep, sweep


@dataclass
class PayoffPoint:
    design: str
    model: str
    pod_racks: int
    tps_per_watt: float
    d_tps_per_watt: float
    effective_dpm: float
    d_cost: float
    payoff: float
    fleet_tps_per_watt: float = 0.0


def serving_gain(model: tp.MoEModel, pod_racks: int, year: int = 2028,
                 scenario: str = proj.HIGH) -> tuple[float, float]:
    """(TPS/W, ΔTPS/W vs single rack) for Kyber-era deployments."""
    base = tp.Deployment(proj.KYBER, year, 1, scenario)
    pod = tp.Deployment(proj.KYBER, year, pod_racks, scenario)
    t0 = tp.tps_per_watt(model, base)
    t1 = tp.tps_per_watt(model, pod)
    return t1, (t1 - t0) / t0


def pod_payoff_study(design: DesignSpec, models: Sequence[tp.MoEModel],
                     pod_sizes: Sequence[int] = (1, 3, 5, 7),
                     env: EnvelopeSpec | None = None, seed: int = 0,
                     year: int = 2028,
                     fleet_cache: Dict[int, fleet.FleetResult] | None = None,
                     ) -> list[PayoffPoint]:
    """Fleet-cost side is model-independent (the hierarchy sees only the
    placement quantum), so fleet sims are run once per pod size and reused
    across models — all missing pod sizes are evaluated in ONE batched
    sweep call (device-sharded when more than one device is visible).
    `fleet_cache` may be shared across designs' calls."""
    env = env or EnvelopeSpec(demand_scale=0.05, gpu_scenario=proj.HIGH,
                              pod_scale_arch=True)
    results: Dict[int, fleet.FleetResult] = fleet_cache if fleet_cache is not None else {}
    missing = [n for n in pod_sizes if n not in results]
    if missing:
        axes = SweepAxes.zip(designs=[design],
                             envs=[replace(env, pod_racks=n)
                                   for n in missing],
                             seeds=[seed])
        res = sharded_sweep(axes)
        for i, n in enumerate(missing):
            results[n] = res.result(i)

    base_cost = results[pod_sizes[0]].effective_dpm
    points = []
    for m in models:
        for n in pod_sizes:
            tw, d_tps = serving_gain(m, n, year)
            d_cost = results[n].effective_dpm / base_cost - 1.0
            payoff = (1 + d_tps) / (1 + d_cost) - 1.0
            # fleet-level TPS/W: deployed GPU MW × per-watt serving rate,
            # normalized by PROVISIONED MW (halls built × HA nameplate).
            # Normalizing by deployed MW would cancel it out of its own
            # formula (fleet_tpw ≡ tw · gpu_share), hiding exactly the
            # stranding penalty the metric exists to expose.
            r = results[n]
            fleet_tps = tw * r.final_deployed_mw * 1e6 * gpu_power_share(env)
            provisioned_w = r.n_halls_built * design.ha_capacity_kw * 1e3
            fleet_tpw = (fleet_tps / provisioned_w if provisioned_w > 0
                         else float("nan"))
            points.append(PayoffPoint(
                design.name, m.name, n, tw, d_tps, r.effective_dpm, d_cost,
                payoff, fleet_tpw))
    return points


@dataclass
class ScenarioPoint:
    """One (scenario, seed) row of the frontier study.

    Deltas are against the paper-baseline configuration with the same
    design and seed from the SAME sweep call (`d_* == 0` for the
    baseline rows themselves).
    """
    family: str             # "baseline" or a scenarios.FAMILIES name
    label: str              # perturbation label within the family
    seed: int
    p50_stranding: float    # final-month p50 over mature halls
    p90_stranding: float    # final-month p90 (the paper's tail metric)
    n_halls: int
    deployed_mw: float
    effective_dpm: float    # lifecycle-effective $/MW
    total_capex: float      # $
    d_p90: float            # p90 stranding delta vs baseline (absolute)
    d_capex: float          # fractional total-capex delta vs baseline
    d_dpm: float            # fractional effective-$/MW delta vs baseline
    # metric-stage columns for `metric_model` (0.0/NaN when stage skipped)
    delivered_tps: float = 0.0    # fleet tokens/s
    dollars_per_tps: float = float("nan")
    d_tps: float = float("nan")   # fractional delivered-TPS delta


def _rel_delta(x: float, ref: float) -> float:
    """Fractional delta `x/ref − 1`, NaN-safe: identical values are
    exactly 0.0 (baseline rows compare against themselves), and any
    non-finite or zero reference yields NaN instead of propagating
    inf through frontier aggregation."""
    if x == ref:
        return 0.0
    if not (np.isfinite(x) and np.isfinite(ref)) or ref == 0:
        return float("nan")
    return float(x / ref - 1.0)


def scenario_frontier(design: DesignSpec,
                      base_env: Optional[EnvelopeSpec] = None,
                      seeds: Sequence[int] = (0,),
                      families: Optional[Dict[str, sc.ScenarioBatch]] = None,
                      sharded: bool = True,
                      metric_model: str = "MoE-132T") -> list[ScenarioPoint]:
    """Beyond-the-paper scenario study (docs/scenarios.md).

    Evaluates `design` on the paper baseline plus every scenario family
    (defaults: `scenarios.all_families(base_env)`) as ONE batched sweep
    call — device-sharded when `sharded` and more than one device is
    visible — and returns one `ScenarioPoint` per (scenario, seed) with
    stranding and effective-capex deltas against the same-seed baseline.

        pts = scenario_frontier(hierarchy.get_design("3+1"),
                                EnvelopeSpec(demand_scale=0.01))
        max(pts, key=lambda p: p.p90_stranding)     # worst-case envelope
    """
    base_env = base_env if base_env is not None else \
        EnvelopeSpec(demand_scale=0.01)
    axes = sc.frontier_axes([design], base=base_env, seeds=seeds,
                            families=families)
    models = tuple(m for m in tp.MODEL_SUITE if m.name == metric_model)
    res = (sharded_sweep if sharded else sweep)(axes, models=models)
    tps = (res.delivered_tps[:, 0] if models
           else np.zeros(len(axes)))
    dpt = (res.dollars_per_tps[:, 0] if models
           else np.full(len(axes), np.nan))

    base_idx = {axes.seeds[i]: i for i in range(len(axes))
                if axes.tags[i] == sc.BASELINE_TAG}
    points = []
    for i in range(len(axes)):
        fam, label = axes.tags[i].split(":", 1)
        j = base_idx[axes.seeds[i]]
        points.append(ScenarioPoint(
            family=fam, label=label, seed=axes.seeds[i],
            p50_stranding=float(res.p50_stranding[i, -1]),
            p90_stranding=float(res.p90_stranding[i, -1]),
            n_halls=int(res.n_halls_built[i]),
            deployed_mw=float(res.final_deployed_mw[i]),
            effective_dpm=float(res.effective_dpm[i]),
            total_capex=float(res.total_capex[i]),
            d_p90=float(res.p90_stranding[i, -1] - res.p90_stranding[j, -1]),
            d_capex=_rel_delta(float(res.total_capex[i]),
                               float(res.total_capex[j])),
            d_dpm=_rel_delta(float(res.effective_dpm[i]),
                             float(res.effective_dpm[j])),
            delivered_tps=float(tps[i]),
            dollars_per_tps=float(dpt[i]),
            d_tps=_rel_delta(float(tps[i]), float(tps[j]))))
    return points


@dataclass
class FrontierPoint:
    """One (design × pod quantum × seed × model) point of the design
    frontier: delivered tokens/s against effective capex."""
    design: str
    tag: str                # scenarios tag, e.g. "pod:p5"
    pod_racks: int
    seed: int
    model: str
    n_halls: int
    deployed_mw: float
    provisioned_mw: float
    p90_stranding: float
    delivered_tps: float
    tps_per_provisioned_w: float
    effective_dpm: float
    total_capex: float
    dollars_per_tps: float
    dominated: bool         # True = strictly beaten on (TPS, capex)


def pareto_dominated(perf: np.ndarray, cost: np.ndarray) -> np.ndarray:
    """Boolean mask over points maximizing `perf` while minimizing `cost`.

    `dominated[i]` is True iff some point j is at least as good on both
    axes and strictly better on one.  Non-finite points (NaN sentinels
    from the cost model) never dominate anything and are always flagged
    dominated."""
    perf = np.asarray(perf, float)
    cost = np.asarray(cost, float)
    finite = np.isfinite(perf) & np.isfinite(cost)
    ge = perf[None, :] >= perf[:, None]          # perf_j ≥ perf_i
    le = cost[None, :] <= cost[:, None]          # cost_j ≤ cost_i
    strict = (perf[None, :] > perf[:, None]) | (cost[None, :] < cost[:, None])
    return (ge & le & strict & finite[None, :]).any(axis=1) | ~finite


def design_frontier(designs: Sequence[DesignSpec] | None = None,
                    base_env: Optional[EnvelopeSpec] = None,
                    pod_sizes: Sequence[int] = (1, 5),
                    models: Sequence[tp.MoEModel] | None = None,
                    seeds: Sequence[int] = (0,),
                    metric_year: int | None = None,
                    sharded: bool = True) -> list[FrontierPoint]:
    """Pareto frontier over the full design grid: delivered tokens/s vs.
    effective capex (the paper's $/performance planning objective).

    Evaluates designs × pod quanta (`scenarios.pod_quanta` tags) × seeds
    as ONE batched, device-sharded sweep whose metric stage prices every
    configuration against `models` (default: the Table 2 suite), then
    flags Pareto-dominated points per model — domination is only
    meaningful between configurations serving the same model.

        pts = design_frontier()               # 4 designs × {1,5}-rack pods
        [p for p in pts if not p.dominated and p.model == "MoE-132T"]
    """
    designs = list(designs) if designs is not None else \
        [hierarchy.get_design(n) for n in ("4N/3", "3+1", "10N/8", "8+2")]
    base_env = base_env if base_env is not None else \
        EnvelopeSpec(demand_scale=0.02, gpu_scenario=proj.HIGH)
    batch = sc.pod_quanta(base_env, pod_sizes=pod_sizes)
    axes = batch.axes(designs, seeds=seeds)
    res = (sharded_sweep if sharded else sweep)(axes, models=models,
                                                metric_year=metric_year)
    if not res.model_names:
        raise ValueError("design_frontier needs a non-empty model suite")

    points = []
    for k, name in enumerate(res.model_names):
        dom = pareto_dominated(res.delivered_tps[:, k], res.total_capex)
        for i in range(len(axes)):
            points.append(FrontierPoint(
                design=axes.designs[i].name, tag=axes.tags[i],
                pod_racks=int(axes.envs[i].pod_racks), seed=axes.seeds[i],
                model=name,
                n_halls=int(res.n_halls_built[i]),
                deployed_mw=float(res.final_deployed_mw[i]),
                provisioned_mw=float(res.provisioned_mw[i]),
                p90_stranding=float(res.p90_stranding[i, -1]),
                delivered_tps=float(res.delivered_tps[i, k]),
                tps_per_provisioned_w=float(res.tps_per_provisioned_w[i, k]),
                effective_dpm=float(res.effective_dpm[i]),
                total_capex=float(res.total_capex[i]),
                dollars_per_tps=float(res.dollars_per_tps[i, k]),
                dominated=bool(dom[i])))
    return points
