"""Single-hall Monte Carlo simulator (paper §4.4).

Each trial: instantiate one hall, place arrivals until SATURATION_FAILS
consecutive placements fail, apply harvesting, resume placement until
another SATURATION_FAILS consecutive failures.  Trials are vmapped; the
event loop is a `lax.scan` over a pre-generated arrival trace.

This module owns the per-trial machinery (`run_trial` and friends); the
batched front end that evaluates whole (design × SKU-kW × policy × seed)
grids in one jitted/vmapped — optionally device-sharded — call lives in
`repro.core.mc_sweep`.  `monte_carlo` here is the exact one-configuration
wrapper over it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import arrivals, placement as pl
from .hierarchy import DesignSpec
from .placement import DEFAULT_POLICY, Deployment, HallState, JaxTopology

SATURATION_FAILS = 100


class TraceArrays(NamedTuple):
    """Device-side trace columns (one entry per event)."""
    rack_kw: jax.Array
    n_racks: jax.Array
    is_gpu: jax.Array
    is_pod: jax.Array
    tier: jax.Array
    harvest_frac: jax.Array

    @staticmethod
    def from_trace(t: arrivals.Trace) -> "TraceArrays":
        return TraceArrays(
            jnp.asarray(t.rack_kw), jnp.asarray(t.n_racks),
            jnp.asarray(t.is_gpu), jnp.asarray(t.is_pod),
            jnp.asarray(t.tier), jnp.asarray(t.harvest_frac))

    def event(self, i) -> Deployment:
        return Deployment(self.rack_kw[i], self.n_racks[i], self.is_gpu[i],
                          self.tier[i], self.is_pod[i])


class TrialResult(NamedTuple):
    state: HallState
    placed: jax.Array          # [E] bool
    rows: jax.Array            # [E, MAX_POD_RACKS]
    counts: jax.Array          # [E, MAX_POD_RACKS]
    saturated: jax.Array       # [] bool — phase ended in saturation


def _fill_phase(jt: JaxTopology, state: HallState, trace: TraceArrays,
                policy, key, with_pods: bool = True) -> TrialResult:
    """Place the trace until saturation.  `with_pods` is static: pod-free
    traces (rack-scale GPUs, `pod_racks=1`) skip `place`'s
    `lax.cond(is_pod, …)` — whose pod branch vmap would evaluate for
    every event — and call the single-row `place_in_row` directly
    (exactly the cluster branch `place` would take)."""
    E = trace.rack_kw.shape[0]
    R = jt.row_cap.shape[0]

    def body(carry, i):
        st, streak = carry
        frozen = streak >= SATURATION_FAILS
        dep = trace.event(i)
        k = jax.random.fold_in(key, i)
        if with_pods:
            st2, ok, rows, counts = pl.place(jt, st, dep, policy, k)
        else:
            st2, ok, rows, counts, _ = pl.place_cluster_in_row(
                jt, st, dep, policy, k, jnp.ones((R,), bool))
        ok = ok & ~frozen
        st = pl._tree_where(ok, st2, st)
        rows = jnp.where(ok, rows, -1)
        counts = jnp.where(ok, counts, 0.0)
        streak = jnp.where(ok, 0, streak + 1)
        return (st, streak), (ok, rows, counts)

    (state, streak), (placed, rows, counts) = jax.lax.scan(
        body, (state, jnp.zeros((), jnp.int32)), jnp.arange(E))
    return TrialResult(state, placed, rows, counts,
                       streak >= SATURATION_FAILS)


def _apply_harvest(jt: JaxTopology, res: TrialResult,
                   trace: TraceArrays) -> HallState:
    """Harvest every placed rack by its class ceiling (paper §5.2)."""
    frac = jnp.where(res.placed, trace.harvest_frac, 0.0)
    return pl.release_bulk(jt, res.state, res.rows, res.counts,
                           trace.rack_kw, trace.is_gpu, trace.tier, frac)


def run_trial(jt: JaxTopology, topo_init: HallState,
              trace_a: TraceArrays, trace_b: TraceArrays,
              policy, key, harvest: bool = True, with_pods: bool = True):
    """One MC trial: fill → harvest → refill.  Returns final state and the
    two phase results.  `harvest` and `with_pods` are static (jit static
    argnames upstream): the non-harvest variant never traces the harvest
    branch, and pod-free traces compile the cheap single-row placement
    (see `_fill_phase`)."""
    ka, kb = jax.random.split(key)
    res_a = _fill_phase(jt, topo_init, trace_a, policy, ka, with_pods)
    state = _apply_harvest(jt, res_a, trace_a) if harvest else res_a.state
    res_b = _fill_phase(jt, state, trace_b, policy, kb, with_pods)
    return res_b.state, res_a, res_b


def monte_carlo(design: DesignSpec, n_trials: int = 32, n_events: int = 600,
                policy: int = DEFAULT_POLICY, seed: int = 0,
                year: int = 2028, scenario: str = "med",
                gpu_power_share: float = 0.6, pod_racks: int = 1,
                quantum_racks: int = 10, harvest: bool = True,
                sku_kw_override: float | None = None,
                single_sku_gpu: bool = False):
    """Run `n_trials` single-hall MC trials.  Returns dict of metrics.

    Exact thin wrapper over the batched engine: one-configuration
    `repro.core.mc_sweep.mc_sweep` call (which also serves whole
    parameter grids — Fig. 6's 21-point kW sweep × 2 designs is ONE
    call there).  Trial traces come from the vectorized
    `arrivals.sample_mixed_traces` (one numpy RNG pass for the whole
    trial batch); `single_sku_gpu` + `sku_kw_override` reproduce the
    paper's Fig. 6 single-SKU sweep (repeated identical GPU deployments
    until saturation) as generator arguments.
    """
    from .mc_sweep import MCAxes, mc_sweep   # deferred: avoids import cycle
    axes = MCAxes.zip(designs=[design], sku_kw=[sku_kw_override],
                      policies=[policy], seeds=[seed])
    res = mc_sweep(axes, n_trials=n_trials, n_events=n_events, year=year,
                   scenario=scenario, gpu_power_share=gpu_power_share,
                   pod_racks=pod_racks, quantum_racks=quantum_racks,
                   harvest=harvest, single_sku_gpu=single_sku_gpu)
    return res.result(0)
