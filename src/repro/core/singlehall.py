"""Single-hall Monte Carlo simulator (paper §4.4).

Each trial: instantiate one hall, place arrivals until SATURATION_FAILS
consecutive placements fail, apply harvesting, resume placement until
another SATURATION_FAILS consecutive failures.  Trials are vmapped; the
event loop is a `lax.scan` over a pre-generated arrival trace.

This module owns the per-trial machinery (`run_trial` and friends); the
batched front end that evaluates whole (design × SKU-kW × policy × seed)
grids in one jitted/vmapped — optionally device-sharded — call lives in
`repro.core.mc_sweep`.  `monte_carlo` here is the exact one-configuration
wrapper over it.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import arrivals, placement as pl
from .hierarchy import DesignSpec
from .placement import DEFAULT_POLICY, Deployment, HallState, JaxTopology

SATURATION_FAILS = 100


class TraceArrays(NamedTuple):
    """Device-side trace columns (one entry per event)."""
    rack_kw: jax.Array
    n_racks: jax.Array
    is_gpu: jax.Array
    is_pod: jax.Array
    tier: jax.Array
    harvest_frac: jax.Array

    @staticmethod
    def from_trace(t: arrivals.Trace) -> "TraceArrays":
        return TraceArrays(
            jnp.asarray(t.rack_kw), jnp.asarray(t.n_racks),
            jnp.asarray(t.is_gpu), jnp.asarray(t.is_pod),
            jnp.asarray(t.tier), jnp.asarray(t.harvest_frac))

    def event(self, i) -> Deployment:
        return Deployment(self.rack_kw[i], self.n_racks[i], self.is_gpu[i],
                          self.tier[i], self.is_pod[i])


class TrialResult(NamedTuple):
    state: HallState
    placed: jax.Array          # [E] bool
    rows: jax.Array            # [E, MAX_POD_RACKS]
    counts: jax.Array          # [E, MAX_POD_RACKS]
    saturated: jax.Array       # [] bool — phase ended in saturation


def _fill_phase(jt: JaxTopology, state: HallState, trace: TraceArrays,
                policy, key, with_pods: bool = True,
                split_pods: bool = False, pod_window: int = 0,
                cluster_start: int = 0,
                pod_scan_len: int = pl.MAX_POD_RACKS,
                hd_scan: int | None = None, use_kernel: bool = False,
                kernel_interpret: bool = False) -> TrialResult:
    """Place the trace until saturation.  Three static placement modes
    (all bit-identical on the same trace — the split modes just avoid
    tracing work `vmap` would otherwise evaluate for every event):

    * ``with_pods=False`` — pod-free traces (rack-scale GPUs,
      `pod_racks=1`) skip `place`'s `lax.cond(is_pod, …)` — whose pod
      branch vmap would evaluate for every event — and call the
      single-row `place_in_row` directly (exactly the cluster branch
      `place` would take).
    * ``with_pods=True, split_pods=False`` — the legacy per-event
      `lax.cond(is_pod, …)` path (`place`), kept compilable as the
      regression/benchmark reference (`legacy_pod_cond=True` upstream).
    * ``with_pods=True, split_pods=True`` — the split-trace fast path:
      the trace must be pods-first (`arrivals.sample_mixed_traces`
      emits it that way), so a **pod window** over events
      ``[0, pod_window)`` (live while ``i < n_pods``, `_place_pod` with
      the static `pod_scan_len` rack scan and the HD-compacted `hd_scan`
      row view) runs first, then a **cluster window** over
      ``[cluster_start, E)`` (live while ``i >= n_pods``,
      `place_cluster_in_row`).  `pod_window` must be ≥ every trial's pod
      count and `cluster_start` ≤ every trial's pod count (upstream
      computes the batch max/min).  Event order, the saturation streak
      and the per-event `fold_in(key, i)` keys are exactly the legacy
      path's, so results are bit-identical.

    `use_kernel` (static) routes every placement's feasibility + score
    through the fused Pallas kernel (`placement.place_in_row`), with
    `kernel_interpret` selecting Pallas interpret mode (CPU CI); results
    are bitwise identical to the jnp path in every mode.
    """
    E = trace.rack_kw.shape[0]
    R = jt.row_cap.shape[0]
    all_rows = jnp.ones((R,), bool)

    if not (with_pods and split_pods):
        def body(carry, i):
            st, streak = carry
            frozen = streak >= SATURATION_FAILS
            dep = trace.event(i)
            k = jax.random.fold_in(key, i)
            if with_pods:
                st2, ok, rows, counts = pl.place(
                    jt, st, dep, policy, k, use_kernel=use_kernel,
                    interpret=kernel_interpret)
            else:
                st2, ok, rows, counts, _ = pl.place_cluster_in_row(
                    jt, st, dep, policy, k, all_rows,
                    use_kernel=use_kernel, interpret=kernel_interpret)
            ok = ok & ~frozen
            st = pl._tree_where(ok, st2, st)
            rows = jnp.where(ok, rows, -1)
            counts = jnp.where(ok, counts, 0.0)
            streak = jnp.where(ok, 0, streak + 1)
            return (st, streak), (ok, rows, counts)

        (state, streak), (placed, rows, counts) = jax.lax.scan(
            body, (state, jnp.zeros((), jnp.int32)), jnp.arange(E))
        return TrialResult(state, placed, rows, counts,
                           streak >= SATURATION_FAILS)

    n_pods = jnp.sum(trace.is_pod.astype(jnp.int32))

    def window_step(place_fn, live_of):
        def body(carry, i):
            st, streak = carry
            frozen = streak >= SATURATION_FAILS
            dep = trace.event(i)
            k = jax.random.fold_in(key, i)
            st2, ok, rows, counts = place_fn(st, dep, k)
            live = live_of(i)
            ok = ok & ~frozen & live
            st = pl._tree_where(ok, st2, st)
            rows = jnp.where(ok, rows, -1)
            counts = jnp.where(ok, counts, 0.0)
            streak = jnp.where(live, jnp.where(ok, 0, streak + 1), streak)
            return (st, streak), (ok, rows, counts)
        return body

    def pod_place(st, dep, k):
        return pl._place_pod(jt, st, dep, policy, k, all_rows,
                             max_racks=pod_scan_len, hd_scan=hd_scan,
                             use_kernel=use_kernel,
                             interpret=kernel_interpret)

    def cluster_place(st, dep, k):
        return pl.place_cluster_in_row(jt, st, dep, policy, k, all_rows,
                                       use_kernel=use_kernel,
                                       interpret=kernel_interpret)[:4]

    carry = (state, jnp.zeros((), jnp.int32))
    placed = jnp.zeros((E,), bool)
    rows = jnp.full((E, pl.MAX_POD_RACKS), -1, jnp.int32)
    counts = jnp.zeros((E, pl.MAX_POD_RACKS), jnp.float32)
    if pod_window > 0:
        carry, (ok_p, rows_p, counts_p) = jax.lax.scan(
            window_step(pod_place, lambda i: i < n_pods), carry,
            jnp.arange(pod_window))
        placed = placed.at[:pod_window].set(ok_p)
        rows = rows.at[:pod_window].set(rows_p)
        counts = counts.at[:pod_window].set(counts_p)
    if cluster_start < E:
        carry, (ok_c, rows_c, counts_c) = jax.lax.scan(
            window_step(cluster_place, lambda i: i >= n_pods), carry,
            jnp.arange(cluster_start, E))
        # the two windows are live-disjoint, so a cluster result only ever
        # lands where the pod window left the -1/0 defaults
        ok_full = jnp.zeros((E,), bool).at[cluster_start:].set(ok_c)
        placed = placed | ok_full
        rows = jnp.where(
            ok_full[:, None],
            jnp.full((E, pl.MAX_POD_RACKS), -1,
                     jnp.int32).at[cluster_start:].set(rows_c), rows)
        counts = jnp.where(
            ok_full[:, None],
            jnp.zeros((E, pl.MAX_POD_RACKS)).at[cluster_start:].set(counts_c),
            counts)
    state, streak = carry
    return TrialResult(state, placed, rows, counts,
                       streak >= SATURATION_FAILS)


def _apply_harvest(jt: JaxTopology, res: TrialResult,
                   trace: TraceArrays) -> HallState:
    """Harvest every placed rack by its class ceiling (paper §5.2)."""
    frac = jnp.where(res.placed, trace.harvest_frac, 0.0)
    return pl.release_bulk(jt, res.state, res.rows, res.counts,
                           trace.rack_kw, trace.is_gpu, trace.tier, frac)


def run_trial(jt: JaxTopology, topo_init: HallState,
              trace_a: TraceArrays, trace_b: TraceArrays,
              policy, key, harvest: bool = True, with_pods: bool = True,
              split_pods: bool = False,
              pod_windows: tuple = (0, 0), cluster_starts: tuple = (0, 0),
              pod_scan_len: int = pl.MAX_POD_RACKS,
              hd_scan: int | None = None, use_kernel: bool = False,
              kernel_interpret: bool = False):
    """One MC trial: fill → harvest → refill.  Returns final state and the
    two phase results.  Every keyword is static (jit static argnames
    upstream): the non-harvest variant never traces the harvest branch,
    pod-free traces compile the cheap single-row placement, and
    `split_pods=True` compiles the split-trace pod fast path —
    `pod_windows` / `cluster_starts` are the (fill, refill) window bounds
    and `pod_scan_len` / `hd_scan` the pod rack-scan trims (see
    `_fill_phase`).  `use_kernel` / `kernel_interpret` route placement
    scoring through the fused Pallas kernel (bitwise-identical results;
    see `placement.place_in_row`)."""
    ka, kb = jax.random.split(key)
    res_a = _fill_phase(jt, topo_init, trace_a, policy, ka, with_pods,
                        split_pods, pod_windows[0], cluster_starts[0],
                        pod_scan_len, hd_scan, use_kernel, kernel_interpret)
    state = _apply_harvest(jt, res_a, trace_a) if harvest else res_a.state
    res_b = _fill_phase(jt, state, trace_b, policy, kb, with_pods,
                        split_pods, pod_windows[1], cluster_starts[1],
                        pod_scan_len, hd_scan, use_kernel, kernel_interpret)
    return res_b.state, res_a, res_b


def monte_carlo(design: DesignSpec, n_trials: int = 32, n_events: int = 600,
                policy: int = DEFAULT_POLICY, seed: int = 0,
                year: int = 2028, scenario: str = "med",
                gpu_power_share: float = 0.6, pod_racks: int = 1,
                quantum_racks: int = 10, harvest: bool = True,
                sku_kw_override: float | None = None,
                single_sku_gpu: bool = False,
                legacy_pod_cond: bool = False,
                use_kernel: bool | None = None,
                kernel_interpret: bool = False):
    """Run `n_trials` single-hall MC trials.  Returns dict of metrics.

    Exact thin wrapper over the batched engine: one-configuration
    `repro.core.mc_sweep.mc_sweep` call (which also serves whole
    parameter grids — Fig. 6's 21-point kW sweep × 2 designs is ONE
    call there).  Trial traces come from the vectorized
    `arrivals.sample_mixed_traces` (one numpy RNG pass for the whole
    trial batch); `single_sku_gpu` + `sku_kw_override` reproduce the
    paper's Fig. 6 single-SKU sweep (repeated identical GPU deployments
    until saturation) as generator arguments.  Pod traces
    (`pod_racks > 1`) compile the split-trace fast path;
    `legacy_pod_cond=True` keeps the per-event `lax.cond(is_pod, …)`
    reference compilable (results are bit-identical).
    """
    from .mc_sweep import MCAxes, mc_sweep   # deferred: avoids import cycle
    axes = MCAxes.zip(designs=[design], sku_kw=[sku_kw_override],
                      policies=[policy], seeds=[seed])
    res = mc_sweep(axes, n_trials=n_trials, n_events=n_events, year=year,
                   scenario=scenario, gpu_power_share=gpu_power_share,
                   pod_racks=pod_racks, quantum_racks=quantum_racks,
                   harvest=harvest, single_sku_gpu=single_sku_gpu,
                   legacy_pod_cond=legacy_pod_cond, use_kernel=use_kernel,
                   kernel_interpret=kernel_interpret)
    return res.result(0)
