"""Single-hall Monte Carlo simulator (paper §4.4).

Each trial: instantiate one hall, place arrivals until SATURATION_FAILS
consecutive placements fail, apply harvesting, resume placement until
another SATURATION_FAILS consecutive failures.  Trials are vmapped; the
event loop is a `lax.scan` over a pre-generated arrival trace.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import arrivals, placement as pl
from .hierarchy import DesignSpec, build_topology
from .placement import (DEFAULT_POLICY, Deployment, HallState, JaxTopology,
                        MAX_POD_RACKS)

SATURATION_FAILS = 100


class TraceArrays(NamedTuple):
    """Device-side trace columns (one entry per event)."""
    rack_kw: jax.Array
    n_racks: jax.Array
    is_gpu: jax.Array
    is_pod: jax.Array
    tier: jax.Array
    harvest_frac: jax.Array

    @staticmethod
    def from_trace(t: arrivals.Trace) -> "TraceArrays":
        return TraceArrays(
            jnp.asarray(t.rack_kw), jnp.asarray(t.n_racks),
            jnp.asarray(t.is_gpu), jnp.asarray(t.is_pod),
            jnp.asarray(t.tier), jnp.asarray(t.harvest_frac))

    def event(self, i) -> Deployment:
        return Deployment(self.rack_kw[i], self.n_racks[i], self.is_gpu[i],
                          self.tier[i], self.is_pod[i])


class TrialResult(NamedTuple):
    state: HallState
    placed: jax.Array          # [E] bool
    rows: jax.Array            # [E, MAX_POD_RACKS]
    counts: jax.Array          # [E, MAX_POD_RACKS]
    saturated: jax.Array       # [] bool — phase ended in saturation


def _fill_phase(jt: JaxTopology, state: HallState, trace: TraceArrays,
                policy, key) -> TrialResult:
    E = trace.rack_kw.shape[0]

    def body(carry, i):
        st, streak = carry
        frozen = streak >= SATURATION_FAILS
        dep = trace.event(i)
        k = jax.random.fold_in(key, i)
        st2, ok, rows, counts = pl.place(jt, st, dep, policy, k)
        ok = ok & ~frozen
        st = pl._tree_where(ok, st2, st)
        rows = jnp.where(ok, rows, -1)
        counts = jnp.where(ok, counts, 0.0)
        streak = jnp.where(ok, 0, streak + 1)
        return (st, streak), (ok, rows, counts)

    (state, streak), (placed, rows, counts) = jax.lax.scan(
        body, (state, jnp.zeros((), jnp.int32)), jnp.arange(E))
    return TrialResult(state, placed, rows, counts,
                       streak >= SATURATION_FAILS)


def _apply_harvest(jt: JaxTopology, res: TrialResult,
                   trace: TraceArrays) -> HallState:
    """Harvest every placed rack by its class ceiling (paper §5.2)."""
    frac = jnp.where(res.placed, trace.harvest_frac, 0.0)
    return pl.release_bulk(jt, res.state, res.rows, res.counts,
                           trace.rack_kw, trace.is_gpu, trace.tier, frac)


def run_trial(jt: JaxTopology, topo_init: HallState,
              trace_a: TraceArrays, trace_b: TraceArrays,
              policy, key, harvest: bool = True):
    """One MC trial: fill → harvest → refill.  Returns final state and the
    two phase results."""
    ka, kb = jax.random.split(key)
    res_a = _fill_phase(jt, topo_init, trace_a, policy, ka)
    state = jax.lax.cond(jnp.asarray(harvest),
                         lambda: _apply_harvest(jt, res_a, trace_a),
                         lambda: res_a.state)
    res_b = _fill_phase(jt, state, trace_b, policy, kb)
    return res_b.state, res_a, res_b


@functools.partial(jax.jit, static_argnames=("policy", "harvest"))
def _run_trials(jt, init, ta, tb, keys, policy, harvest):
    """Vmapped trials; jit-cached across same-shaped topologies/traces so
    parameter sweeps (Fig. 6) compile once."""
    return jax.vmap(lambda t_a, t_b, k: run_trial(jt, init, t_a, t_b,
                                                  policy, k, harvest)
                    )(ta, tb, keys)


def monte_carlo(design: DesignSpec, n_trials: int = 32, n_events: int = 600,
                policy: int = DEFAULT_POLICY, seed: int = 0,
                year: int = 2028, scenario: str = "med",
                gpu_power_share: float = 0.6, pod_racks: int = 1,
                quantum_racks: int = 10, harvest: bool = True,
                sku_kw_override: float | None = None,
                single_sku_gpu: bool = False):
    """Run `n_trials` single-hall MC trials.  Returns dict of metrics.

    `single_sku_gpu` + `sku_kw_override` reproduce the paper's Fig. 6
    single-SKU sweep (repeated identical GPU deployments until saturation).
    """
    topo = build_topology(design)
    jt = pl.jax_topology(topo)
    init = pl.init_state(topo)

    tas, tbs = [], []
    for i in range(n_trials):
        if single_sku_gpu:
            t = arrivals.sample_mixed_trace(n_events, year, scenario,
                                            seed + 7919 * i, 1.0,
                                            pod_racks, quantum_racks)
            t.rack_kw[:] = sku_kw_override
            t.class_id[:] = 0
            t.is_gpu[:] = True
        else:
            t = arrivals.sample_mixed_trace(n_events, year, scenario,
                                            seed + 7919 * i, gpu_power_share,
                                            pod_racks, quantum_racks)
            if sku_kw_override is not None:
                t.rack_kw[t.is_gpu] = sku_kw_override
        tas.append(t)
        tbs.append(arrivals.sample_mixed_trace(
            max(200, n_events // 3), year, scenario, seed + 7919 * i + 1,
            1.0 if single_sku_gpu else gpu_power_share, pod_racks,
            quantum_racks))
        if single_sku_gpu:
            tbs[-1].rack_kw[:] = sku_kw_override
            tbs[-1].is_gpu[:] = True

    stack = lambda ts: jax.tree.map(lambda *xs: jnp.stack(xs),
                                    *[TraceArrays.from_trace(t) for t in ts])
    ta, tb = stack(tas), stack(tbs)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_trials)

    state, res_a, res_b = _run_trials(jt, init, ta, tb, keys, policy,
                                      harvest)

    lineup_str = jax.vmap(lambda s: pl.lineup_stranding(jt, s))(state)
    hall_str = jax.vmap(lambda s: pl.hall_stranding(jt, s))(state)[:, 0]
    deployed = jax.vmap(pl.deployed_kw)(state)
    return {
        "lineup_stranding": np.asarray(lineup_str),   # [T, X]
        "hall_stranding": np.asarray(hall_str),       # [T]
        "deployed_kw": np.asarray(deployed),          # [T]
        "ha_capacity_kw": design.ha_capacity_kw,
        "saturated": np.asarray(res_b.saturated),
        "placed_a": np.asarray(res_a.placed),
        "placed_b": np.asarray(res_b.placed),
    }
