"""Hardware and cost projections (paper Appendix B).

Package-level TDP scenarios (Eq. 19), per-package capability growth
(Table 4), deployment-architecture parameters (Table 3) and derived rack
power (Eq. 23 / Table 5), plus non-GPU rack-power trajectories (App. B.2).

Anchors are reverse-engineered from the published tables (see tests —
`test_projections.py` asserts agreement with Table 4/5 within tolerance).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

LOW, MED, HIGH = "low", "med", "high"
SCENARIOS = (LOW, MED, HIGH)
# Package-TDP growth per scenario (Eq. 19): g_s ∈ {5%, 12.5%, 20%}.
TDP_GROWTH = {LOW: 0.05, MED: 0.125, HIGH: 0.20}


@dataclass(frozen=True)
class DeploymentArch:
    """Table 3: deployment architecture parameters."""
    name: str
    available: int
    n_pkg: int                 # packages per deployment unit (one rack)
    dies_per_pkg: int
    nvl_domain_pkgs: int       # local NVLink-domain size (packages)
    b_nvl_tbps: float          # aggregate unidirectional NVLink BW / domain
    b_ib_tbps: float           # aggregate scale-out BW / deployment unit
    ovhd_kw: float             # non-package overhead power


DGX_H200 = DeploymentArch("DGX-H200", 2024, 8, 1, 8, 3.6, 0.4, 3.0)
OBERON = DeploymentArch("Blackwell-Oberon", 2025, 72, 1, 72, 64.8, 7.2, 25.0)
VERA_RUBIN = DeploymentArch("Vera Rubin NVL72", 2026, 72, 2, 72, 259.2, 14.4, 30.0)
KYBER = DeploymentArch("Kyber / Rubin Ultra", 2027, 144, 4, 144, 750.0, 57.6, 35.0)
DEPLOYMENT_ARCHS = {a.name: a for a in (DGX_H200, OBERON, VERA_RUBIN, KYBER)}


# --- Package TDP anchors (kW/package), reverse-engineered from Table 5 ---
# Oberon line: anchored at B200 (2025), re-anchored at Vera Rubin (2026),
# growth resumes from the 2026 anchor.  Kyber line: anchored at Rubin Ultra
# (2027), held fixed through 2028, growth resumes 2029.
_OBERON_2025 = {LOW: (157 - 25) / 72, MED: (180 - 25) / 72, HIGH: (203 - 25) / 72}
_OBERON_2026 = {LOW: (160 - 30) / 72, MED: (178 - 30) / 72, HIGH: (196 - 30) / 72}
_KYBER_2027 = {LOW: (515 - 35) / 144, MED: (600 - 35) / 144, HIGH: (685 - 35) / 144}


def pkg_tdp_kw(year: int, scenario: str, line: str = "oberon") -> float:
    """Eq. 19: P_pkg(τ, s) = P_anchor(s) · (1+g_s)^(τ−τ_anchor)."""
    g = TDP_GROWTH[scenario]
    if line == "oberon":
        if year <= 2025:
            return _OBERON_2025[scenario]
        return _OBERON_2026[scenario] * (1 + g) ** (year - 2026)
    elif line == "kyber":
        if year < 2027:
            raise ValueError("Kyber available 2027+")
        base = _KYBER_2027[scenario]
        if year <= 2028:
            return base
        return base * (1 + g) ** (year - 2028)
    raise ValueError(f"unknown line {line!r}")


def deployment_arch_for(year: int, pod_scale: bool) -> DeploymentArch:
    """Architecture in service for new deployments in `year` (App. B.1)."""
    if pod_scale and year >= 2027:
        return KYBER
    if year >= 2026:
        return VERA_RUBIN
    if year >= 2025:
        return OBERON
    return DGX_H200


def gpu_rack_kw(year: int, scenario: str, pod_scale: bool = False) -> float:
    """Eq. 23 / Table 5: rack power = N_pkg · P_pkg + P_ovhd.

    Uses the published Table 5 values verbatim where available (the paper's
    own table deviates slightly from Eq. 19 in the High scenario); falls
    back to the Eq. 19/23 model outside the table range.
    """
    arch = deployment_arch_for(year, pod_scale)
    table = TABLE5_KYBER if arch is KYBER else TABLE5_OBERON
    idx = {LOW: 0, MED: 1, HIGH: 2}[scenario]
    y = min(max(year, min(table)), max(table))
    if y in table:
        base = float(table[y][idx])
        if year <= max(table):
            return base
        # extrapolate past 2034 with Eq. 19 growth on the package share
        ovhd = arch.ovhd_kw
        g = TDP_GROWTH[scenario]
        return (base - ovhd) * (1 + g) ** (year - max(table)) + ovhd
    line = "kyber" if arch is KYBER else "oberon"
    return arch.n_pkg * pkg_tdp_kw(year, scenario, line) + arch.ovhd_kw


# --- Per-package performance (Table 4): FP4 PFLOP/s, HBM TB/s, HBM GB ---
# Post-anchor extrapolation (2029+): +30%/yr FLOPs, +15%/yr HBM BW,
# +25%/yr HBM capacity.
_PERF_GROWTH = {"flops": 0.30, "hbm_bw": 0.15, "hbm_gb": 0.25}


def pkg_perf(year: int, line: str = "oberon") -> Dict[str, float]:
    if line == "oberon":
        if year <= 2025:
            return {"flops_pf": 10.0, "hbm_bw_tbps": 8.0, "hbm_gb": 192.0}
        base = {"flops_pf": 50.0, "hbm_bw_tbps": 22.0, "hbm_gb": 288.0}
        t = max(0, year - 2028)
    elif line == "kyber":
        base = {"flops_pf": 100.0, "hbm_bw_tbps": 32.0, "hbm_gb": 1024.0}
        t = max(0, year - 2028)
    else:
        raise ValueError(line)
    return {
        "flops_pf": base["flops_pf"] * (1 + _PERF_GROWTH["flops"]) ** t,
        "hbm_bw_tbps": base["hbm_bw_tbps"] * (1 + _PERF_GROWTH["hbm_bw"]) ** t,
        "hbm_gb": base["hbm_gb"] * (1 + _PERF_GROWTH["hbm_gb"]) ** t,
    }


# --- Non-GPU rack power (App. B.2) ---
# Anchors: general compute 20 kW (2025), storage 15 kW (2025).  Growth rates
# chosen to hit the published 2034 endpoints ({26,38,52} kW and {18,22,26} kW
# — the paper's nominal {3,5,8}%/{2,4,6}% rates do not reproduce its own
# endpoints for compute; we match endpoints, see DESIGN.md §4).
_COMPUTE_2034 = {LOW: 26.0, MED: 38.0, HIGH: 52.0}
_STORAGE_2034 = {LOW: 18.0, MED: 22.0, HIGH: 26.0}


def compute_rack_kw(year: int, scenario: str = MED) -> float:
    g = (_COMPUTE_2034[scenario] / 20.0) ** (1.0 / 9.0) - 1.0
    return 20.0 * (1 + g) ** (year - 2025)


def storage_rack_kw(year: int, scenario: str = MED) -> float:
    g = (_STORAGE_2034[scenario] / 15.0) ** (1.0 / 9.0) - 1.0
    return 15.0 * (1 + g) ** (year - 2025)


# Published Table 5 rack power (kW) for validation.
TABLE5_OBERON = {  # year: (low, med, high)
    2025: (157, 180, 203), 2026: (160, 178, 196), 2027: (166, 197, 226),
    2028: (173, 218, 262), 2029: (180, 243, 341), 2030: (188, 271, 434),
    2031: (197, 303, 545), 2032: (205, 339, 677), 2033: (214, 379, 836),
    2034: (224, 425, 1025),
}
TABLE5_KYBER = {
    2027: (515, 600, 685), 2028: (515, 600, 685), 2029: (539, 671, 815),
    2030: (564, 750, 971), 2031: (591, 839, 1158), 2032: (619, 940, 1382),
    2033: (648, 1053, 1652), 2034: (679, 1180, 1975),
}
