"""Component-based infrastructure cost model (paper §5.3, Table 6).

Comparative, not predictive: all designs are costed under the same
per-component assumptions; topology only changes which components (and how
many reserve units) a hall needs.

Calibration notes (DESIGN.md §4): the Table 6 column sums to $10.381M/MW —
the paper's quoted 3+1 block cost (~$10.3M/MW).  Distributed designs need no
static transfer switches (failover is absorbed by per-line-up reserve), so
4N/3 = Table 6 − STS ≈ $10.13M/MW (~paper's $10M), reproducing the ~3%
static gap of §3.1.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from .hierarchy import DesignSpec

# Table 6: $ per MW of IT capacity.
TABLE6 = {
    "ups": 1_000_000,
    "battery": 275_000,
    "generators": 750_000,
    "mv_transformers": 120_000,
    "mv_switchgear": 60_000,
    "lv_switchboards": 150_000,
    "ats": 70_000,
    "sts": 250_000,
    "row_distribution": 100_000,
    "busbar_overhead": 6_000,
    "cooling": 3_000_000,
    "shell_site_engineering": 1_800_000,
    "fitout_other": 2_800_000,
}

# Electrical power-train components whose installed count scales with the
# reserve ratio (used for the Fig. 14 reserve/stranding decomposition).
POWERTRAIN = ("ups", "battery", "generators", "lv_switchboards", "ats", "sts")


def component_costs_per_mw(design: DesignSpec) -> Dict[str, float]:
    c = dict(TABLE6)
    if design.kind == "distributed":
        c["sts"] = 0.0           # no block-transfer path
        # dual/quad-feed busway runs: scale busbar overhead with mean feeds
        mean_feeds = (design.ld_rows * design.ld_feeds +
                      design.hd_rows * design.hd_feeds) / design.n_rows
        c["busbar_overhead"] = TABLE6["busbar_overhead"] * mean_feeds / 2.0
    return c


def initial_dollars_per_mw(design: DesignSpec) -> float:
    """Initial $/MW: hall CapEx normalized by nameplate HA capacity."""
    return sum(component_costs_per_mw(design).values())


def hall_capex(design: DesignSpec) -> float:
    return initial_dollars_per_mw(design) * design.ha_capacity_kw / 1000.0


def reserve_cost_per_mw(design: DesignSpec) -> float:
    """$/MW attributable to reserve electrical capacity: the (x−y)/x share
    of the installed power train (Fig. 14 decomposition)."""
    c = component_costs_per_mw(design)
    reserve_ratio = (design.n_lineups - design.n_active) / design.n_lineups
    return reserve_ratio * sum(c[k] for k in POWERTRAIN)


def effective_dollars_per_mw(design: DesignSpec, n_halls: int,
                             deployed_mw: float) -> float:
    """Effective $/MW = Σ K_i / Σ P̂_i (paper §4.3).

    NaN (not inf) when nothing is deployed: the metric is *undefined* for
    an empty fleet, and a NaN sentinel survives aggregation arithmetic as
    "no data" where inf used to poison frontier deltas with ±inf
    (`payoff` masks non-finite values explicitly)."""
    if deployed_mw <= 0:
        return float("nan")
    return n_halls * hall_capex(design) / deployed_mw


def stranding_cost_per_mw(design: DesignSpec, n_halls: int,
                          deployed_mw: float) -> float:
    """Effective − initial $/MW: infrastructure built but not deployable."""
    return (effective_dollars_per_mw(design, n_halls, deployed_mw)
            - initial_dollars_per_mw(design))


def dollars_per_tps(total_capex: float, delivered_tps: float) -> float:
    """Effective $ per delivered token/s — the paper's headline
    $/performance objective.  NaN when nothing is delivered."""
    if not (delivered_tps > 0):
        return float("nan")
    return total_capex / delivered_tps
