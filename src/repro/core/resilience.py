"""Resilient sweep execution: checkpoint/resume, fault isolation,
validation (the durability layer under giant grids and the future
sweep service — see docs/reliability.md).

A `giant_grid`-scale run is ~20 minutes of compute; a crash, an OOM, or
one pathological configuration used to lose the whole grid.  This module
wraps the chunked dispatch from the sweep engines with three guarantees:

* **Durable per-chunk checkpointing** — the batch is prepared ONCE
  (`sweep._prepare` / `mc_sweep._mc_prepare`), sliced into fixed chunks,
  and each chunk's raw device slab is committed through the atomic
  `checkpoint.Checkpointer` (write-temp → `os.replace` → fsynced COMMIT
  marker, sha256-checksummed payload).  A run manifest pins the input
  fingerprint (prepared-arg bytes + statics + code salt + chunk grid);
  an interrupted run re-prepares, matches the fingerprint, loads the
  committed chunks and computes only the rest.  Because every chunk is
  a slice of the same prepared batch evaluated by the same jitted
  callable, the resumed result is **bitwise identical** to an
  uninterrupted run (the chunked ≡ one-shot property proven in
  `tests/test_mesh2d.py`).

* **Chunk-level fault isolation** — a failing chunk is retried on an
  exponential `runtime.fault.Backoff` schedule, then bisected so only
  the genuinely poisoned configurations are quarantined: their rows
  become NaN-sentinel results (ints −1, bools False) and the structured
  `RunReport.quarantined` lists them; every other row is bitwise
  unchanged.  NaN appearing in fields that are never legitimately NaN
  (`final_deployed_kw` / `placed_fraction`; MC `deployed_kw`) is treated
  the same way.  OOM (real `RESOURCE_EXHAUSTED` or injected) halves the
  dispatch size — stickily, so later chunks stream at the size that
  fits — while the checkpoint grid keeps the original chunk boundaries.

* **Validated inputs** — `axes.validate()` runs before any compile time
  is spent (`SweepValidationError` with the offending field).

`FaultPlan` is the deterministic fault-injection harness the tests and
the `resilience_*` benchmark legs drive: fail chunk k's first j
attempts, inject OOM at a chosen halving depth, poison configurations
(every evaluation of a range containing one crashes), inject NaN rows,
or crash the process right after a chosen chunk commits.

    res = resilient_sweep(axes, chunk_size=128, checkpoint_dir="ckpt/")
    res.report.quarantined, res.report.chunks_resumed, ...
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer, ChecksumError
from ..runtime.fault import Backoff
from . import placement as pl
from .fleet import SimOutputs
from .hierarchy import SweepValidationError
from .mc_sweep import MCAxes, MCResult, _mc_finalize, _mc_prepare, \
    _mc_sweep_jit
from .sweep import SweepAxes, SweepResult, _finalize, _prepare, _sweep_jit

# Version salt folded into the run fingerprint: bump on any change to
# the executor or the engines that affects numerics or slab layout, so
# stale checkpoints can never be resumed into a differently-coded run.
SALT = "resilience-v1"
RUN_MANIFEST = "run_manifest.json"

SWEEP_FIELDS = SimOutputs._fields
MC_FIELDS = ("lineup_stranding", "hall_stranding", "deployed_kw",
             "saturated", "placed_a", "placed_b")
# Quarantine metadata rides inside each chunk's slab dict as plain
# arrays (string-free), so resume reconstructs the report.
_Q_KEYS = ("__q_idx", "__q_reason", "__q_attempts")

REASON_CRASH, REASON_OOM, REASON_NAN = 1, 2, 3
REASONS = {REASON_CRASH: "crash", REASON_OOM: "oom", REASON_NAN: "nan-output"}
_REASON_CODES = {v: k for k, v in REASONS.items()}


# ---------------------------------------------------------------------------
# fault-injection harness
# ---------------------------------------------------------------------------

class SimulatedOOM(MemoryError):
    """Injected out-of-memory failure (stands in for RESOURCE_EXHAUSTED)."""


class InjectedFault(RuntimeError):
    """Injected transient/poison evaluation failure."""


class InjectedCrash(RuntimeError):
    """Injected process death after a chunk commit (kill-and-resume
    tests); escapes `resilient_sweep` by design."""


class ResumeMismatchError(RuntimeError):
    """The checkpoint directory belongs to a different run (fingerprint
    mismatch): different axes/traces/statics/chunk grid or code salt.
    Clear the directory (or point at a fresh one) to proceed."""


@dataclass
class FaultPlan:
    """Deterministic fault injection for the resilient executor.

    fail:  chunk → n: the chunk's first n full-range attempts raise
           `InjectedFault` (exercises retry/backoff; attempt n+1 wins).
    oom:   chunk → depth: evaluations of any range in that chunk wider
           than `chunk_len // 2**depth` raise `SimulatedOOM`, forcing
           exactly `depth` dispatch-size halvings.
    poison: global config indices; EVERY evaluation of a range
           containing one raises, driving bisection down to quarantine
           exactly those indices.
    nan:   global config indices whose output rows are overwritten with
           NaN after a successful evaluation (quarantined as
           "nan-output" after bisection).
    crash_after: chunk index; `InjectedCrash` is raised right after that
           chunk commits (the kill in kill-and-resume).
    """
    fail: Dict[int, int] = field(default_factory=dict)
    oom: Dict[int, int] = field(default_factory=dict)
    poison: Tuple[int, ...] = ()
    nan: Tuple[int, ...] = ()
    crash_after: Optional[int] = None
    _fail_seen: Dict[int, int] = field(default_factory=dict)
    _oom_seen: Dict[int, int] = field(default_factory=dict)

    def before_eval(self, chunk: int, lo: int, hi: int,
                    chunk_lo: int, chunk_hi: int) -> None:
        if lo == chunk_lo and hi == chunk_hi:
            seen = self._fail_seen.get(chunk, 0)
            if seen < self.fail.get(chunk, 0):
                self._fail_seen[chunk] = seen + 1
                raise InjectedFault(
                    f"injected failure: chunk {chunk} attempt {seen + 1}")
        depth = self.oom.get(chunk, 0)
        if depth and hi - lo > (chunk_hi - chunk_lo) // (1 << depth):
            raise SimulatedOOM(
                f"injected OOM: chunk {chunk} range [{lo}, {hi})")
        bad = [p for p in self.poison if lo <= p < hi]
        if bad:
            raise InjectedFault(
                f"poisoned configuration(s) {bad} in range [{lo}, {hi})")

    def after_eval(self, lo: int, hi: int, slab: Dict[str, np.ndarray]):
        rows = [p - lo for p in self.nan if lo <= p < hi]
        if rows:
            slab = dict(slab)
            for name, arr in slab.items():
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.copy()
                    arr[rows] = np.nan
                    slab[name] = arr
        return slab

    def after_commit(self, chunk: int) -> None:
        if self.crash_after is not None and chunk == self.crash_after:
            raise InjectedCrash(
                f"injected crash after committing chunk {chunk}")


# ---------------------------------------------------------------------------
# run report
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QuarantinedConfig:
    """One quarantined configuration (NaN-sentinel row in the result)."""
    index: int           # global configuration index
    reason: str          # "crash" | "oom" | "nan-output"
    error: str           # exception text ("" when reloaded from disk)
    attempts: int        # evaluation attempts spent on this config


@dataclass
class RunReport:
    """What the resilient executor did (attached as `result.report`)."""
    n_configs: int
    chunk_size: int
    n_chunks: int
    fingerprint: str
    chunks_computed: int = 0
    chunks_resumed: int = 0
    retries: int = 0
    oom_halvings: int = 0
    quarantined: List[QuarantinedConfig] = field(default_factory=list)

    def quarantined_indices(self) -> Tuple[int, ...]:
        return tuple(sorted(q.index for q in self.quarantined))


# ---------------------------------------------------------------------------
# fingerprint + manifest
# ---------------------------------------------------------------------------

def _fingerprint(args, statics: dict, B: int, chunk_size: int) -> str:
    """sha256 over the prepared input batch, the static compile knobs,
    the chunk grid and the code salt — everything the per-chunk slabs
    depend on.  Matching fingerprints ⇒ committed chunks are verbatim
    slices of the run being resumed."""
    h = hashlib.sha256()
    h.update(SALT.encode())
    h.update(f"B={B};chunk={chunk_size}".encode())
    h.update(repr(sorted(statics.items(), key=lambda kv: kv[0])).encode())
    for leaf in jax.tree.leaves(args):
        a = np.asarray(leaf)
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()


def _clear_chunks(directory: str) -> None:
    for name in os.listdir(directory):
        if name.startswith("step_"):
            shutil.rmtree(os.path.join(directory, name),
                          ignore_errors=True)


def _open_run(directory: str, fingerprint: str, B: int, chunk_size: int,
              n_chunks: int) -> bool:
    """Create or match the run manifest.  Returns True when committed
    chunks may be resumed (valid manifest, same fingerprint).  A
    corrupt/alien manifest discards any existing chunks and starts
    fresh; a well-formed manifest for a *different* run raises
    `ResumeMismatchError` instead of silently clobbering it."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, RUN_MANIFEST)
    if os.path.exists(path):
        try:
            with open(path) as f:
                m = json.load(f)
            ok = isinstance(m, dict) and isinstance(m.get("fingerprint"), str)
        except (json.JSONDecodeError, OSError):
            m, ok = None, False
        if ok:
            if m["fingerprint"] == fingerprint:
                return True
            raise ResumeMismatchError(
                f"{directory} holds a different run (fingerprint "
                f"{m['fingerprint'][:12]}… ≠ {fingerprint[:12]}…); clear "
                f"it or use a fresh checkpoint_dir")
        _clear_chunks(directory)        # torn manifest ⇒ chunks unprovable
    elif any(n.startswith("step_") for n in os.listdir(directory)):
        _clear_chunks(directory)        # chunks without a manifest
    meta = {"fingerprint": fingerprint, "salt": SALT, "n_configs": B,
            "chunk_size": chunk_size, "n_chunks": n_chunks}
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)               # atomic manifest publish
    return False


# ---------------------------------------------------------------------------
# chunk executor
# ---------------------------------------------------------------------------

def _is_oom(e: BaseException) -> bool:
    return isinstance(e, MemoryError) or "RESOURCE_EXHAUSTED" in str(e)


class _ChunkExecutor:
    """Evaluate `B` configurations in chunks with checkpointing, retry,
    bisection quarantine, and OOM halving.  `raw_eval(lo, hi)` returns
    the device output pytree for configurations `[lo, hi)` of the
    globally prepared batch; `fields` orders its leaves into the slab
    dict; NaN in a `detect` field marks a poisoned row."""

    def __init__(self, raw_eval: Callable, fields: Sequence[str],
                 detect: Sequence[str], B: int, chunk_size: int,
                 checkpoint_dir: Optional[str], plan: Optional[FaultPlan],
                 backoff: Optional[Backoff]):
        self.raw_eval = raw_eval
        self.fields = tuple(fields)
        self.detect = tuple(detect)
        self.B = B
        self.chunk = max(1, min(int(chunk_size), B))
        self.n_chunks = -(-B // self.chunk)
        self.plan = plan if plan is not None else FaultPlan()
        self.backoff = backoff if backoff is not None else Backoff()
        self.eval_size = self.chunk     # sticky OOM-halved dispatch width
        self.ckpt = (Checkpointer(checkpoint_dir, keep=10 ** 9)
                     if checkpoint_dir else None)

    # ---- slab helpers ----
    def _to_slab(self, out) -> Dict[str, np.ndarray]:
        leaves = out if isinstance(out, tuple) and not hasattr(out, "_fields") \
            else [getattr(out, f) for f in self.fields]
        return {f: np.asarray(x) for f, x in zip(self.fields, leaves)}

    def _nan_slab(self, lo: int, hi: int) -> Dict[str, np.ndarray]:
        """Sentinel slab for quarantined rows: floats NaN, ints −1,
        bools False.  Shapes come from `jax.eval_shape` (no compile)."""
        shapes = jax.eval_shape(lambda: self.raw_eval(lo, hi))
        leaves = (shapes if isinstance(shapes, tuple)
                  and not hasattr(shapes, "_fields")
                  else [getattr(shapes, f) for f in self.fields])
        slab = {}
        for f, s in zip(self.fields, leaves):
            dt = np.dtype(s.dtype)
            if np.issubdtype(dt, np.floating):
                fill = np.nan
            elif dt == np.bool_:
                fill = False
            else:
                fill = -1
            slab[f] = np.full(s.shape, fill, dt)
        return slab

    def _concat(self, slabs: Sequence[Dict[str, np.ndarray]]):
        return {f: np.concatenate([s[f] for s in slabs])
                for f in self.fields}

    def _bad_rows(self, slab: Dict[str, np.ndarray]) -> np.ndarray:
        """Rows whose never-NaN fields came back NaN (poisoned output).
        Only `detect` fields are scanned — quantile/metric columns carry
        legitimate NaN sentinels."""
        bad = None
        for f in self.detect:
            v = np.isnan(slab[f])
            v = v.reshape(v.shape[0], -1).any(axis=1) if v.ndim > 1 else v
            bad = v if bad is None else (bad | v)
        return bad

    # ---- fault-isolated evaluation ----
    def _quarantine(self, report: RunReport, idx: int, reason: int,
                    error: str, attempts: int):
        report.quarantined.append(QuarantinedConfig(
            index=idx, reason=REASONS[reason], error=error,
            attempts=attempts))

    def _eval_range(self, report: RunReport, chunk: int, lo: int, hi: int,
                    chunk_lo: int, chunk_hi: int, retries: int):
        """Evaluate `[lo, hi)` with retry → bisection → quarantine."""
        attempt = 0
        while True:
            try:
                self.plan.before_eval(chunk, lo, hi, chunk_lo, chunk_hi)
                slab = self._to_slab(self.raw_eval(lo, hi))
                slab = self.plan.after_eval(lo, hi, slab)
                bad = self._bad_rows(slab)
                if not bad.any():
                    return slab
                if hi - lo == 1:
                    self._quarantine(report, lo, REASON_NAN,
                                     "NaN in non-NaN output field",
                                     attempt + 1)
                    return self._nan_slab(lo, hi)
                # NaN output is deterministic — bisect without retries
                mid = (lo + hi) // 2
                return self._concat([
                    self._eval_range(report, chunk, lo, mid, chunk_lo,
                                     chunk_hi, 0),
                    self._eval_range(report, chunk, mid, hi, chunk_lo,
                                     chunk_hi, 0)])
            except InjectedCrash:
                raise
            except Exception as e:      # noqa: BLE001 — isolate anything
                if _is_oom(e):
                    report.oom_halvings += 1
                    self.eval_size = max(self.eval_size // 2, 1)
                    if hi - lo == 1:
                        self._quarantine(report, lo, REASON_OOM, str(e),
                                         attempt + 1)
                        return self._nan_slab(lo, hi)
                    mid = (lo + hi) // 2
                    return self._concat([
                        self._eval_range(report, chunk, lo, mid, chunk_lo,
                                         chunk_hi, retries),
                        self._eval_range(report, chunk, mid, hi, chunk_lo,
                                         chunk_hi, retries)])
                if attempt < retries:
                    self.backoff.sleep(attempt)
                    attempt += 1
                    report.retries += 1
                    continue
                if hi - lo == 1:
                    self._quarantine(report, lo, REASON_CRASH, str(e),
                                     attempt + 1)
                    return self._nan_slab(lo, hi)
                # retries exhausted on a multi-config range: bisect to
                # isolate the poisoned configuration(s); halves get no
                # further retries (the transient budget is spent)
                mid = (lo + hi) // 2
                return self._concat([
                    self._eval_range(report, chunk, lo, mid, chunk_lo,
                                     chunk_hi, 0),
                    self._eval_range(report, chunk, mid, hi, chunk_lo,
                                     chunk_hi, 0)])

    def _eval_chunk(self, report: RunReport, c: int, lo: int, hi: int):
        """One chunk, streamed at the (possibly OOM-halved) dispatch
        width."""
        parts, pos = [], lo
        while pos < hi:
            end = min(pos + self.eval_size, hi)
            parts.append(self._eval_range(
                report, c, pos, end, lo, hi,
                retries=self.backoff.max_retries))
            pos = end
        return parts[0] if len(parts) == 1 else self._concat(parts)

    # ---- the run ----
    def run(self):
        """Returns `(slab, report)` with `slab` the concatenated
        `[B, …]` field dict."""
        report = RunReport(n_configs=self.B, chunk_size=self.chunk,
                           n_chunks=self.n_chunks, fingerprint="")
        resume_ok, done = False, set()
        if self.ckpt is not None:
            fp = self._run_fingerprint
            report.fingerprint = fp
            resume_ok = _open_run(self.ckpt.dir, fp, self.B, self.chunk,
                                  self.n_chunks)
            if resume_ok:
                done = set(self.ckpt.all_steps())

        slabs = []
        for c in range(self.n_chunks):
            lo, hi = c * self.chunk, min((c + 1) * self.chunk, self.B)
            slab = None
            if resume_ok and c in done:
                try:
                    leaves, _meta = self.ckpt.load(step=c, verify=True)
                    slab = dict(zip(sorted(self.fields + _Q_KEYS), leaves))
                    for q_i, q_r, q_a in zip(slab["__q_idx"],
                                             slab["__q_reason"],
                                             slab["__q_attempts"]):
                        self._quarantine(report, int(q_i), int(q_r), "",
                                         int(q_a))
                    report.chunks_resumed += 1
                except Exception:   # ChecksumError/torn read ⇒ recompute
                    slab = None
            if slab is None:
                n_q = len(report.quarantined)
                slab = self._eval_chunk(report, c, lo, hi)
                report.chunks_computed += 1
                new_q = report.quarantined[n_q:]
                slab["__q_idx"] = np.asarray(
                    [q.index for q in new_q], np.int64)
                slab["__q_reason"] = np.asarray(
                    [_REASON_CODES[q.reason] for q in new_q], np.int8)
                slab["__q_attempts"] = np.asarray(
                    [q.attempts for q in new_q], np.int32)
                if self.ckpt is not None:
                    self.ckpt.save(c, slab, blocking=True)
                    self.plan.after_commit(c)
                else:
                    self.plan.after_commit(c)
            slabs.append(slab)
        return self._concat(slabs), report

    _run_fingerprint: str = ""          # set by the front doors


# ---------------------------------------------------------------------------
# front doors
# ---------------------------------------------------------------------------

def _sliced_eval(args, jit_fn, statics: dict):
    """Range evaluator over the globally prepared batch.  A width-1 vmap
    compiles a degenerate batch whose accumulation order differs bitwise
    from wider dispatches (observed on XLA:CPU), so single-config ranges
    duplicate their row to width 2 and keep row 0 — bitwise identical to
    the same row inside any wider dispatch."""
    def raw_eval(lo, hi):
        if hi - lo == 1:
            idx = jnp.asarray([lo, lo])
            sl = jax.tree.map(lambda x: x[idx], args)
            out = jit_fn(*sl, **statics)
            return jax.tree.map(lambda x: x[:1], out)
        sl = jax.tree.map(lambda x: x[lo:hi], args)
        return jit_fn(*sl, **statics)
    return raw_eval


def _mask_rows(report: RunReport, *arrays: np.ndarray) -> None:
    """NaN the derived float columns of quarantined rows (the raw slab
    already carries sentinels; `_finalize` recomputes per-design cost
    columns that must not survive for quarantined configurations)."""
    idx = list(report.quarantined_indices())
    if not idx:
        return
    for a in arrays:
        if a is not None and np.issubdtype(np.asarray(a).dtype,
                                           np.floating):
            a[idx] = np.nan


def resilient_sweep(axes: SweepAxes, chunk_size: int | None = None,
                    checkpoint_dir: str | None = None,
                    fault_plan: FaultPlan | None = None,
                    backoff: Backoff | None = None,
                    harvest: bool = True, mature_months: int = 12,
                    n_halls_max: int = 0, traces=None, models=None,
                    metric_year: int | None = None,
                    use_kernel: bool | None = None,
                    kernel_interpret: bool = False,
                    exact_quantiles: bool = True,
                    quantile_bins: int | None = None) -> SweepResult:
    """`sweep.sweep` behind the resilient chunk executor.

    The batch is prepared once, evaluated chunk-by-chunk through the
    unsharded jitted engine (slices of one prepared batch ⇒ bitwise
    identity with the one-shot result regardless of chunk boundaries,
    resumes, or bisection descents), and optionally checkpointed per
    chunk.  Returns a `SweepResult` whose `report` is the `RunReport`;
    quarantined configurations carry NaN-sentinel rows.  Multi-device
    sharding stays with `sweep.sharded_sweep` — durability and mesh
    dispatch compose at the service layer, not here.

    Args beyond `sweep.sweep`:
        chunk_size: configurations per checkpointed chunk (default: the
            whole batch as one chunk).
        checkpoint_dir: directory for the run manifest + per-chunk
            checkpoints; None disables durability (isolation/validation
            still apply).  Resuming into a directory whose manifest
            fingerprint does not match raises `ResumeMismatchError`.
        fault_plan: deterministic fault injection (tests/benchmarks).
        backoff: retry schedule for failing chunks (default
            `runtime.fault.Backoff()`).
    """
    args, months, topos, X_pad, with_pods, pod_len, hd_scan = _prepare(
        axes, n_halls_max, traces)
    statics = dict(harvest=harvest, mature_months=mature_months,
                   with_pods=with_pods, pod_scan_len=pod_len,
                   hd_scan=hd_scan,
                   use_kernel=pl.resolve_use_kernel(use_kernel),
                   kernel_interpret=kernel_interpret,
                   exact_quantiles=exact_quantiles,
                   quantile_bins=quantile_bins)
    B = len(axes)
    chunk = chunk_size if chunk_size is not None else B

    raw_eval = _sliced_eval(args, _sweep_jit, statics)
    ex = _ChunkExecutor(raw_eval, SWEEP_FIELDS,
                        detect=("final_deployed_kw", "placed_fraction"),
                        B=B, chunk_size=chunk,
                        checkpoint_dir=checkpoint_dir, plan=fault_plan,
                        backoff=backoff)
    if checkpoint_dir:
        ex._run_fingerprint = _fingerprint(args, statics, B, ex.chunk)
    slab, report = ex.run()
    out = SimOutputs(**{f: slab[f] for f in SWEEP_FIELDS})
    res = _finalize(out, axes, months, topos, X_pad, mature_months,
                    models=models, metric_year=metric_year)
    _mask_rows(report, res.initial_dpm, res.effective_dpm,
               res.total_capex, res.provisioned_mw, res.delivered_tps,
               res.tps_per_provisioned_w, res.dollars_per_tps)
    res.report = report
    return res


def resilient_mc_sweep(axes: MCAxes, chunk_size: int | None = None,
                       checkpoint_dir: str | None = None,
                       fault_plan: FaultPlan | None = None,
                       backoff: Backoff | None = None,
                       n_trials: int = 32, n_events: int = 600,
                       year: int = 2028, scenario: str = "med",
                       gpu_power_share: float = 0.6, pod_racks: int = 1,
                       quantum_racks: int = 10, la_fraction: float = 0.0,
                       harvest: bool = True, single_sku_gpu: bool = False,
                       refill_events: int | None = None, models=None,
                       use_kernel: bool | None = None,
                       kernel_interpret: bool = False) -> MCResult:
    """`mc_sweep.mc_sweep` behind the resilient chunk executor (see
    `resilient_sweep`; chunks slice the configuration axis, trials ride
    inside their configuration)."""
    args, statics = _mc_prepare(axes, n_trials, n_events, year, scenario,
                                gpu_power_share, pod_racks, quantum_racks,
                                la_fraction, single_sku_gpu, refill_events)
    kw = dict(harvest=harvest,
              use_kernel=pl.resolve_use_kernel(use_kernel),
              kernel_interpret=kernel_interpret, **statics)
    B = len(axes)
    chunk = chunk_size if chunk_size is not None else B

    raw_eval = _sliced_eval(args, _mc_sweep_jit, kw)
    ex = _ChunkExecutor(raw_eval, MC_FIELDS, detect=("deployed_kw",),
                        B=B, chunk_size=chunk,
                        checkpoint_dir=checkpoint_dir, plan=fault_plan,
                        backoff=backoff)
    if checkpoint_dir:
        ex._run_fingerprint = _fingerprint(args, kw, B, ex.chunk)
    slab, report = ex.run()
    out = tuple(slab[f] for f in MC_FIELDS)
    res = _mc_finalize(out, axes, models=models, year=year,
                       scenario=scenario,
                       gpu_share=1.0 if single_sku_gpu else gpu_power_share,
                       pod_racks=pod_racks)
    _mask_rows(report, res.ha_capacity_kw, res.provisioned_mw,
               res.delivered_tps, res.tps_per_provisioned_w,
               res.dollars_per_tps)
    res.report = report
    return res
