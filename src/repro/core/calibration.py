"""HLO-calibrated throughput model (beyond-paper; DESIGN.md §2).

The paper's Appendix-A per-token costs are first-order analytic estimates.
This module replaces them with measurements from *our own compiled serving
steps*: the multi-pod dry-run (`repro.launch.dryrun`) records per-device
HLO FLOPs, HBM bytes, and collective bytes for every (architecture ×
shape × mesh) cell; `cost_scale_from_dryrun` converts a cell's artifact
into a `CostScale` so the fleet/payoff studies run on compiled-system
numbers instead of closed forms.

Dry-run artifact schema (JSON, one file per cell):
    {
      "arch": str, "shape": str, "mesh": str, "n_devices": int,
      "flops_per_device": float,        # compiled.cost_analysis()
      "bytes_per_device": float,
      "collective_bytes_per_device": float,   # HLO collective operand sum
      "batch": int, "seq": int, "step": "train"|"prefill"|"decode",
    }
"""
from __future__ import annotations

import json
import os
from typing import Dict

from . import throughput as tp


def load_artifact(path: str) -> Dict:
    with open(path) as f:
        return json.load(f)


def tokens_in_step(art: Dict) -> float:
    if art["step"] == "decode":
        return float(art["batch"])          # one new token per sequence
    return float(art["batch"]) * float(art["seq"])


def cost_scale_from_dryrun(art: Dict, model: tp.MoEModel,
                           phase: str = "dec") -> tp.CostScale:
    """CostScale multipliers = measured per-token cost / analytic cost.

    The measured numerator is global (per-device × n_devices) per token of
    the compiled step; the analytic denominator is the paper's Eq. 6–11
    estimate for the same phase.  A multiplier > 1 means the compiled
    system does more work than the first-order model assumes (e.g. remat,
    dispatch overhead); < 1 means the model over-counts.
    """
    n_tok = tokens_in_step(art)
    n_dev = float(art["n_devices"])
    flops_tok = art["flops_per_device"] * n_dev / n_tok
    bytes_tok = art["bytes_per_device"] * n_dev / n_tok
    coll_tok = art["collective_bytes_per_device"] * n_dev / n_tok

    if phase == "pre":
        c_ref = float(tp.c_prefill(model, model.S))
        m_ref = float(tp.m_prefill(model, model.S))
    else:
        c_ref = float(tp.c_decode(model, model.S))
        m_ref = float(tp.m_decode(model, model.S))
    n_ref = float(tp.n_tp(model, 8) + tp.n_ep(model))

    return tp.CostScale(
        compute=max(flops_tok / c_ref, 1e-6),
        memory=max(bytes_tok / m_ref, 1e-6),
        comm=max(coll_tok / n_ref, 1e-6),
    )


def calibrated_scales(dryrun_dir: str, model: tp.MoEModel,
                      step: str = "decode") -> Dict[str, tp.CostScale]:
    """Scan a dry-run artifact directory → {cell_name: CostScale}."""
    out = {}
    if not os.path.isdir(dryrun_dir):
        return out
    for fn in sorted(os.listdir(dryrun_dir)):
        if not fn.endswith(".json"):
            continue
        art = load_artifact(os.path.join(dryrun_dir, fn))
        if art.get("step") != step:
            continue
        phase = "pre" if step == "prefill" else "dec"
        out[fn[:-5]] = cost_scale_from_dryrun(art, model, phase)
    return out
