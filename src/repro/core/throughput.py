"""MoE inference throughput model (paper §5.4, Appendix A).

Three-resource min-bottleneck model per phase (Eq. 5):
    TPS^φ(m, D) = min( F_D / C^φ(m),  B_D^HBM / M^φ(m),  1 / T_comm^φ(m,D) )
with per-token compute/memory costs (Eqs. 6–9), TP/EP communication
(Eqs. 10–16) under the HBM-residency locality model (Eqs. 12–13), and
request-level aggregation (Eq. 17; see DESIGN.md §4 for the dimensional
reading we implement).

Traceability contract: the locality integers (`n_units`, `n_domains`,
Eq. 12) are genuinely static per (model, deployment) pair — they round
byte counts with `ceil` — so they can never be traced.  `PairStatics`
hoists everything that depends on them (bandwidths, comm times, power)
into one precomputed record; the `*_s` evaluators below it are pure jnp
over those statics, so a whole configurations × models grid evaluates
as ONE jitted call (`tps_request_grid` / `tps_per_watt_grid`, the sweep
engines' metric stage).  The scalar API (`tps_prefill`, `tps_request`,
…) is the single-pair wrapper over the same evaluators.

`CostScale` lets `core.calibration` replace the first-order analytic
coefficients with HLO-measured ones (beyond-paper).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, replace
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import projections as proj

# Serving conventions (App. A.1): FP8 weights, FP4 activations/KV, B=256.
B_W = 1.0          # bytes / weight
B_ACT = 0.5        # bytes / activation element
B_KV = 0.5         # bytes / KV element
BATCH = 256
ALPHA_HBM = 0.7    # usable HBM fraction (Eq. 12)


@dataclass(frozen=True)
class MoEModel:
    """Appendix A.5, Table 2."""
    name: str
    L: int
    w: int
    E: int
    K: int = 2
    S: int = 1024          # evaluation context (= prompt) length

    @property
    def FF(self) -> int:
        return 4 * self.w

    @property
    def w_total_bytes(self) -> float:
        # all experts + shared attention:  L(4w² + E·2·w·FF)·b_w
        return self.L * (4 * self.w ** 2 + self.E * 2 * self.w * self.FF) * B_W

    @property
    def w_active_bytes(self) -> float:
        return self.L * (4 * self.w ** 2 + self.K * 2 * self.w * self.FF) * B_W


# Table 2 model suite (0.6T – 401T nominal).
MODEL_SUITE = (
    MoEModel("MoE-0.6T", 48, 6144, 64),
    MoEModel("MoE-5T", 96, 8192, 96),
    MoEModel("MoE-19T", 120, 12288, 128),
    MoEModel("MoE-51T", 120, 14336, 256),
    MoEModel("MoE-132T", 120, 16384, 512),
    MoEModel("MoE-401T", 144, 18432, 1024),
)
MODELS = {m.name: m for m in MODEL_SUITE}


@dataclass(frozen=True)
class Deployment:
    """A rack- or pod-scale accelerator deployment (App. B.1/B.2).

    Locality semantics (§6.5 / DESIGN.md §4): a *pod* deployment
    (`pod_fabric=True`, n_racks>1) exposes its constituent racks as one
    local high-bandwidth domain ("shared low-latency pod fabric", §5.2);
    rack-scale deployments keep Eq. 24's per-rack NVLink domain.  When a
    model needs more domains than the deployment provides, serving spans
    `n_units ≥ n_racks` co-scheduled units over the scale-out fabric.
    """
    arch: proj.DeploymentArch
    year: int
    n_racks: int = 1            # pod size (1 = rack-scale)
    scenario: str = proj.MED
    pod_fabric: bool = True     # pods form one local domain (§6.5)
    incast_penalty: bool = True  # remote EP shares B_IB across domain pairs

    @property
    def line(self) -> str:
        return "kyber" if self.arch is proj.KYBER else "oberon"

    @property
    def perf(self):
        return proj.pkg_perf(self.year, self.line)

    @property
    def domain_pkgs(self) -> int:
        """Packages per local high-bandwidth domain."""
        if self.pod_fabric and self.n_racks > 1:
            return self.arch.nvl_domain_pkgs * self.n_racks
        return self.arch.nvl_domain_pkgs

    def n_units(self, m: "MoEModel") -> int:
        """Racks/pods co-scheduled so the model fits in HBM (≥ n_racks)."""
        usable_per_rack = ALPHA_HBM * self.arch.n_pkg * self.hbm_pkg_bytes
        need = int(np.ceil(m.w_total_bytes / usable_per_rack))
        return max(self.n_racks, need)

    def n_pkg(self, m: "MoEModel") -> int:
        return self.arch.n_pkg * self.n_units(m)

    def f_flops(self, m: "MoEModel") -> float:      # Eq. 20 (FLOP/s)
        return self.n_pkg(m) * self.perf["flops_pf"] * 1e15

    def b_hbm(self, m: "MoEModel") -> float:        # Eq. 21 (bytes/s)
        return self.n_pkg(m) * self.perf["hbm_bw_tbps"] * 1e12

    @property
    def hbm_pkg_bytes(self) -> float:
        return self.perf["hbm_gb"] * 1e9

    @property
    def b_nvl(self) -> float:                        # per-domain (bytes/s)
        bw = self.arch.b_nvl_tbps * 1e12
        if self.pod_fabric and self.n_racks > 1:
            bw *= self.n_racks                       # pod fabric spine
        return bw

    def b_ib(self, m: "MoEModel") -> float:          # aggregate (bytes/s)
        return self.arch.b_ib_tbps * 1e12 * self.n_units(m)

    @property
    def tp_degree(self) -> int:                      # T_D
        return self.arch.nvl_domain_pkgs

    def power_w(self, m: "MoEModel" = None) -> float:   # Eq. 25
        rack_kw = proj.gpu_rack_kw(self.year, self.scenario,
                                   pod_scale=self.arch is proj.KYBER)
        n = self.n_racks if m is None else self.n_units(m)
        return rack_kw * n * 1e3


def serving_deployment(year: int, scenario: str, pod_racks: int = 1,
                       pod_scale: bool | None = None) -> Deployment:
    """The serving `Deployment` implied by a simulator operating point:
    the architecture in service for `year` (`projections
    .deployment_arch_for`, pod-scale Kyber racks when pods are in play)
    at the envelope's placement quantum.  Shared by the sweep engines'
    metric stage and `payoff`."""
    pod_racks = max(int(pod_racks), 1)
    pod_scale = pod_racks > 1 if pod_scale is None else bool(pod_scale)
    arch = proj.deployment_arch_for(year, pod_scale)
    return Deployment(arch, year, pod_racks, scenario)


class CostScale(NamedTuple):
    """Multipliers applied to the analytic per-token costs — identity by
    default; `core.calibration` sets these from compiled-HLO measurements."""
    compute: float = 1.0
    memory: float = 1.0
    comm: float = 1.0


IDENT = CostScale()

DTYPE = jnp.float32    # one dtype for every traced per-token cost


# --- per-token costs (Eqs. 6–11) ---

def c_prefill(m: MoEModel, s_p):                  # Eq. 6 (FLOPs/token)
    s_p = jnp.asarray(s_p, DTYPE)
    return float(m.L) * (4.0 * m.K * m.w * m.FF + 4.0 * m.w ** 2
                         + 2.0 * m.w * s_p)


def c_decode(m: MoEModel, t):                     # Eq. 7
    t = jnp.asarray(t, DTYPE)
    return float(m.L) * (4.0 * m.K * m.w * m.FF + 4.0 * m.w ** 2
                         + 2.0 * m.w * t)


def m_prefill(m: MoEModel, s_p, batch=BATCH):     # Eq. 8 (bytes/token)
    return m.w_total_bytes / (batch * s_p) + 2 * m.L * m.w * B_KV


def m_decode(m: MoEModel, t, batch=BATCH):        # Eq. 9
    t = jnp.asarray(t, DTYPE)
    return m.w_active_bytes / batch + 2.0 * m.L * m.w * (t + 1.0) * B_KV


def n_tp(m: MoEModel, t_d):                       # Eq. 10 (bytes/token)
    return m.L * 2 * (t_d - 1) / t_d * m.w * B_ACT


def n_ep(m: MoEModel):                            # Eq. 11
    return 2 * m.L * m.K * m.w * B_ACT


# --- locality model (Eqs. 12–16) ---

def n_domains(m: MoEModel, d: Deployment):        # Eq. 12
    usable = ALPHA_HBM * d.domain_pkgs * d.hbm_pkg_bytes
    return int(np.ceil(m.w_total_bytes / usable))


def f_ib(m: MoEModel, d: Deployment):             # Eq. 13
    nd = n_domains(m, d)
    return 0.0 if nd == 1 else 1.0 - 1.0 / nd


def t_comm(m: MoEModel, d: Deployment, scale: CostScale = IDENT):
    """Eqs. 14–16.  Pure host-float math over the pair's locality
    statics (no dtype/shape forks) — `PairStatics` records the unscaled
    value so grids never re-derive it inside a trace."""
    tp = n_tp(m, d.tp_degree) / d.b_nvl                      # Eq. 14
    f = f_ib(m, d)
    nd = n_domains(m, d)
    b_ib = d.b_ib(m)
    if d.incast_penalty and nd > 1:
        b_ib = b_ib / nd       # per-domain-pair share of the scale-out fabric
    ep = max((1 - f) * n_ep(m) / d.b_nvl,                    # Eq. 15
             f * n_ep(m) / b_ib if f > 0 else 0.0)
    return scale.comm * (tp + ep)                            # Eq. 16


# --- precomputed pair statics (the vmap-safe layer) ---

class PairStatics(NamedTuple):
    """Everything Eqs. 5–18 need about one (model, deployment) pair,
    with the static `ceil`-derived integers (`n_units`, `n_domains`)
    already folded in.  Leaves are host floats for one pair
    (`pair_statics`) or [C, M] jnp arrays for a deployments × models
    grid (`grid_statics`); the `*_s` evaluators are pure jnp over any
    leaf shape."""
    c0: object       # constant FLOPs/token (Eqs. 6/7 shared term)
    c1: object       # context-linear FLOPs/token coefficient (2·L·w)
    m_pre: object    # prefill bytes/token at (s_p, batch) (Eq. 8)
    m_dec0: object   # decode bytes/token constant (Eq. 9)
    m_dec1: object   # decode bytes/token per (t+1): 2·L·w·b_kv
    s_p: object      # prompt length
    f_flops: object  # Eq. 20
    b_hbm: object    # Eq. 21
    t_comm: object   # Eqs. 14–16, unscaled
    t_kv: object     # Eq. 18 per-request-batch KV transfer time
    power_w: object  # Eq. 25 over the co-scheduled units


def resolve_model(m) -> MoEModel:
    """Accept a `MoEModel` or a Table 2 model name (key of `MODELS`)."""
    return MODELS[m] if isinstance(m, str) else m


def pair_statics(m: MoEModel, d: Deployment, s_p=None,
                 batch=BATCH) -> PairStatics:
    """Host-side statics for one (model, deployment) pair — the only
    place the Python `int`/`ceil` casts live."""
    m = resolve_model(m)
    s_p = float(m.S if s_p is None else s_p)
    return PairStatics(
        c0=float(m.L) * (4.0 * m.K * m.w * m.FF + 4.0 * m.w ** 2),
        c1=2.0 * m.L * m.w,
        m_pre=m.w_total_bytes / (batch * s_p) + 2 * m.L * m.w * B_KV,
        m_dec0=m.w_active_bytes / batch,
        m_dec1=2.0 * m.L * m.w * B_KV,
        s_p=s_p,
        f_flops=d.f_flops(m),
        b_hbm=d.b_hbm(m),
        t_comm=t_comm(m, d),
        t_kv=t_kv_transfer(m, s_p, d.b_ib(m)),
        power_w=d.power_w(m),
    )


def grid_statics(models: Sequence[MoEModel], deployments: Sequence[Deployment],
                 batch=BATCH) -> PairStatics:
    """[C, M] statics for a deployments × models grid (C deployments,
    M models), ready for the jitted `*_s` evaluators."""
    rows = [[pair_statics(m, d, batch=batch) for m in models]
            for d in deployments]
    return PairStatics(*(jnp.asarray(
        [[getattr(st, f) for st in row] for row in rows], DTYPE)
        for f in PairStatics._fields))


# --- phase & request throughput (Eqs. 5, 17, 18) ---
# `mode="min"` is Eq. 5 as printed (full overlap: slowest resource binds).
# `mode="additive"` follows limitation A.4(3) — no overlap between comm and
# compute/memory: T_token = max(T_compute, T_memory) + T_comm.  The additive
# mode is the default for the §6.5 pod study (see DESIGN.md §4).
DEFAULT_MODE = "additive"


def _combine(t_comp, t_mem, t_cm, mode):
    if mode == "min":
        return 1.0 / jnp.maximum(jnp.maximum(t_comp, t_mem), t_cm)
    return 1.0 / (jnp.maximum(t_comp, t_mem) + t_cm)


def _f32(st: PairStatics) -> PairStatics:
    return PairStatics(*(jnp.asarray(x, DTYPE) for x in st))


def tps_prefill_s(st: PairStatics, scale: CostScale = IDENT,
                  mode=DEFAULT_MODE):
    """Eq. 5, prefill phase — pure jnp over statics of any shape."""
    st = _f32(st)
    t_comp = scale.compute * (st.c0 + st.c1 * st.s_p) / st.f_flops
    t_mem = scale.memory * st.m_pre / st.b_hbm
    return _combine(t_comp, t_mem, scale.comm * st.t_comm, mode)


def tps_decode_s(st: PairStatics, t, scale: CostScale = IDENT,
                 mode=DEFAULT_MODE):
    """Eq. 5, decode phase at context length `t` (broadcastable)."""
    st = _f32(st)
    t = jnp.asarray(t, DTYPE)
    t_comp = scale.compute * (st.c0 + st.c1 * t) / st.f_flops
    t_mem = scale.memory * (st.m_dec0 + st.m_dec1 * (t + 1.0)) / st.b_hbm
    return _combine(t_comp, t_mem, scale.comm * st.t_comm, mode)


def tps_request_s(st: PairStatics, s_out: int = 256,
                  scale: CostScale = IDENT, batch=BATCH, mode=DEFAULT_MODE):
    """Request-level throughput (Eq. 17, dimensional reading per
    DESIGN.md): T_total = B·S_p/TPS_pre + Σ_t B/TPS_dec(t) + T_KV;
    TPS_req = B·S_out / T_total [tokens/s].  Pure jnp: the decode sum
    broadcasts a trailing context axis against statics of any shape, so
    a [C, M] grid is one fused evaluation."""
    st = _f32(st)
    t_pre = batch * st.s_p / tps_prefill_s(st, scale, mode)
    st_b = PairStatics(*(x[..., None] for x in st))
    ts = st.s_p[..., None] + jnp.arange(1, s_out + 1, dtype=DTYPE)
    t_dec = jnp.sum(batch / tps_decode_s(st_b, ts, scale, mode), axis=-1)
    return batch * s_out / (t_pre + t_dec + st.t_kv)


def tps_per_watt_s(st: PairStatics, s_out: int = 256,
                   scale: CostScale = IDENT, batch=BATCH, mode=DEFAULT_MODE):
    st = _f32(st)
    return tps_request_s(st, s_out, scale, batch, mode) / st.power_w


@functools.partial(jax.jit, static_argnames=("s_out", "batch", "mode",
                                             "per_watt"))
def _grid_jit(st, scale, s_out, batch, mode, per_watt):
    fn = tps_per_watt_s if per_watt else tps_request_s
    return fn(st, s_out, scale, batch, mode)


def tps_request_grid(models: Sequence[MoEModel],
                     deployments: Sequence[Deployment], s_out: int = 256,
                     scale: CostScale = IDENT, batch=BATCH,
                     mode=DEFAULT_MODE) -> jnp.ndarray:
    """[C, M] request throughput for a deployments × models grid in ONE
    jitted call (C deployments, M models) — the batched metric stage the
    sweep engines consume.  Equals the scalar `tps_request` per pair
    (`tests/test_metric_stack.py` pins grid ≡ loop)."""
    st = grid_statics(models, deployments, batch=batch)
    return _grid_jit(st, scale, s_out, batch, mode, False)


def tps_per_watt_grid(models: Sequence[MoEModel],
                      deployments: Sequence[Deployment], s_out: int = 256,
                      scale: CostScale = IDENT, batch=BATCH,
                      mode=DEFAULT_MODE) -> jnp.ndarray:
    """[C, M] tokens/s per serving watt (Eq. 25 normalization)."""
    st = grid_statics(models, deployments, batch=batch)
    return _grid_jit(st, scale, s_out, batch, mode, True)


def tps_prefill(m: MoEModel, d: Deployment, s_p=None,
                scale: CostScale = IDENT, batch=BATCH, mode=DEFAULT_MODE):
    return tps_prefill_s(pair_statics(m, d, s_p, batch), scale, mode)


def tps_decode(m: MoEModel, d: Deployment, t,
               scale: CostScale = IDENT, batch=BATCH, mode=DEFAULT_MODE):
    return tps_decode_s(pair_statics(m, d, batch=batch), t, scale, mode)


def t_kv_transfer(m: MoEModel, s_p, b_transfer):  # Eq. 18
    return 2 * m.L * m.w * s_p * B_KV / b_transfer


def tps_request(m: MoEModel, d: Deployment, s_out: int = 256,
                scale: CostScale = IDENT, batch=BATCH, mode=DEFAULT_MODE):
    """Request-level throughput for one pair (Eq. 17) — the scalar
    wrapper over `tps_request_s`."""
    return tps_request_s(pair_statics(m, d, batch=batch), s_out, scale,
                         batch, mode)


def tps_per_watt(m: MoEModel, d: Deployment, s_out: int = 256,
                 scale: CostScale = IDENT, mode=DEFAULT_MODE):
    return float(tps_request(m, d, s_out, scale, mode=mode)) / d.power_w(m)


def bottleneck(m: MoEModel, d: Deployment, phase: str = "dec", t: int = 1024,
               scale: CostScale = IDENT):
    """Which of the three terms binds (for analysis/plots)."""
    if phase == "pre":
        terms = {
            "compute": float(scale.compute * c_prefill(m, m.S)) / d.f_flops(m),
            "memory": float(scale.memory * m_prefill(m, m.S)) / d.b_hbm(m),
            "comm": t_comm(m, d, scale),
        }
    else:
        terms = {
            "compute": float(scale.compute * c_decode(m, t)) / d.f_flops(m),
            "memory": float(scale.memory * m_decode(m, t)) / d.b_hbm(m),
            "comm": t_comm(m, d, scale),
        }
    return max(terms, key=terms.get), terms
