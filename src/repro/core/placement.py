"""Hierarchical multi-resource placement engine (paper §4.2, App. C.1).

Pure-JAX implementation: hall state is a pytree of arrays, a placement is a
pure function step, Monte-Carlo trials are `vmap`-ed and arrival sequences
are `lax.scan`-ned.  The same engine serves the single-hall simulator
(H = 1) and the fleet simulator (rows/line-ups globally indexed over H
halls, with an activation mask).

Feasibility (Eq. 26): a placement is admitted iff every ancestor node —
row (power/air/liquid/tiles), line-ups (power under redundancy), hall
(liquid plant) — retains capacity.  Redundancy semantics:

* distributed xN/y (HA): every feeding parent p must simultaneously hold
  failover headroom   (y/x)·C_p − ha_load_p ≥ Δ(P, k) = P/(k−1)    (Eq. 1/27)
  and each takes the balanced share P/k on admission.
* distributed (LA): may consume reserve — total load ≤ full rating C_p.
* block N+k: rows draw from one primary at full rating; reserve line-ups
  admit no load (quantization, Eq. 2).

Placement policies (paper §4.2, Fig. 7): random, round-robin, min-waste
(best fit), variance-minimization (default; minimizes post-placement UPS
load imbalance — implemented via the exact sufficient-statistic reduction:
argmin Var(loads') ≡ argmin Σ_{p∈feeds} [2·l̂_p·s + s²], s = P/(k·C)).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .hierarchy import HallTopology, MAX_FEEDS
from .resources import LIQ, N_RES, POWER, TIER_HA, rack_demand
from ..kernels.placement_score.ops import score_rows as _kernel_score_rows

# Policy ids (paper §4.2).
POLICY_RANDOM, POLICY_ROUND_ROBIN, POLICY_MIN_WASTE, POLICY_VAR_MIN = 0, 1, 2, 3
POLICY_NAMES = ("random", "round_robin", "min_waste", "var_min")
DEFAULT_POLICY = POLICY_VAR_MIN

MAX_POD_RACKS = 8      # static bound on pod size (paper studies 3–7)
_BIG = 1e30
_LD_PREFERENCE = 100.0  # non-GPU racks prefer LD rows (paper §2.2)

# Pallas kernel path (see docs/architecture.md "kernel path").  The row
# block size trades VMEM footprint against grid steps; 128 rows × 8-lane
# feed tiles stay far under VMEM for every in-repo topology, and
# `kernels.placement_score.kernel.placement_score` pads the row axis to
# a multiple internally, so the value is a tile size, not a constraint.
DEFAULT_BLOCK_R = 128


def default_use_kernel() -> bool:
    """Kernel dispatch default: on for TPU backends, off elsewhere (the
    interpreted Pallas path is correct on CPU but slower than jnp; CI
    exercises it explicitly via `interpret=True`)."""
    return jax.default_backend() == "tpu"


def resolve_use_kernel(use_kernel) -> bool:
    """Host-level resolution of a `use_kernel` engine flag: `None` means
    backend default (`default_use_kernel`)."""
    return default_use_kernel() if use_kernel is None else bool(use_kernel)


class JaxTopology(NamedTuple):
    """Device-resident mirror of `HallTopology`."""
    row_cap: jax.Array      # [R, N_RES]
    row_feeds: jax.Array    # [R, MAX_FEEDS] int32
    row_nfeeds: jax.Array   # [R] int32
    row_is_hd: jax.Array    # [R] bool
    row_domain: jax.Array   # [R] int32
    row_hall: jax.Array     # [R] int32
    hd_index: jax.Array     # [R] int32 — HD row ids first (ascending), then
                            # the rest; `hd_index[:n_hd]` is the compacted
                            # HD-row view the pod scans gather over
    lineup_cap: jax.Array   # [X]
    lineup_is_active: jax.Array  # [X] bool
    lineup_hall: jax.Array  # [X] int32 — hall owning each line-up
    hall_liq_cap: jax.Array  # [H]
    ha_frac: jax.Array      # scalar
    is_block: jax.Array     # scalar bool


def jax_topology(topo: HallTopology) -> JaxTopology:
    # stable: HD rows keep their ascending id order, so a compacted argmin
    # tie-breaks exactly like the full-row argmin restricted to HD rows
    hd_index = np.argsort(~np.asarray(topo.row_is_hd), kind="stable")
    return JaxTopology(
        row_cap=jnp.asarray(topo.row_cap),
        row_feeds=jnp.asarray(topo.row_feeds),
        row_nfeeds=jnp.asarray(topo.row_nfeeds),
        row_is_hd=jnp.asarray(topo.row_is_hd),
        row_domain=jnp.asarray(topo.row_domain),
        row_hall=jnp.asarray(topo.row_hall),
        hd_index=jnp.asarray(hd_index, jnp.int32),
        lineup_cap=jnp.asarray(topo.lineup_cap),
        lineup_is_active=jnp.asarray(topo.lineup_is_active),
        lineup_hall=jnp.asarray(topo.lineup_hall, jnp.int32),
        hall_liq_cap=jnp.asarray(topo.hall_liq_cap),
        ha_frac=jnp.asarray(topo.ha_frac, jnp.float32),
        is_block=jnp.asarray(topo.is_block),
    )


class HallState(NamedTuple):
    row_load: jax.Array     # [R, N_RES]
    lineup_ha: jax.Array    # [X]  HA load (balanced shares)
    lineup_tot: jax.Array   # [X]  HA + LA load
    hall_liq: jax.Array     # [H]  liquid plant load (LPM)
    rr_cursor: jax.Array    # []   round-robin cursor


def init_state(topo: HallTopology) -> HallState:
    return _empty_state(topo.row_cap.shape[0], topo.lineup_cap.shape[0],
                        topo.n_halls)


def init_state_from(jt: JaxTopology) -> HallState:
    """Empty state shaped after a device topology (usable inside jit/vmap)."""
    return _empty_state(jt.row_cap.shape[0], jt.lineup_cap.shape[0],
                        jt.hall_liq_cap.shape[0])


def _empty_state(R: int, X: int, H: int) -> HallState:
    return HallState(
        row_load=jnp.zeros((R, N_RES), jnp.float32),
        lineup_ha=jnp.zeros((X,), jnp.float32),
        lineup_tot=jnp.zeros((X,), jnp.float32),
        hall_liq=jnp.zeros((H,), jnp.float32),
        rr_cursor=jnp.zeros((), jnp.int32),
    )


class Deployment(NamedTuple):
    """One arrival: a same-SKU cluster (one row) or a GPU pod (multi-row)."""
    rack_kw: jax.Array   # f32 per-rack power
    n_racks: jax.Array   # i32
    is_gpu: jax.Array    # bool
    tier: jax.Array      # i32 (0=HA, 1=LA)
    is_pod: jax.Array    # bool — racks may span rows within one domain

    @staticmethod
    def make(rack_kw, n_racks=1, is_gpu=False, tier=TIER_HA, is_pod=False):
        return Deployment(jnp.asarray(rack_kw, jnp.float32),
                          jnp.asarray(n_racks, jnp.int32),
                          jnp.asarray(is_gpu, bool),
                          jnp.asarray(tier, jnp.int32),
                          jnp.asarray(is_pod, bool))


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _gather_feeds(jt: JaxTopology, state: HallState, row_feeds=None):
    idx = jt.row_feeds if row_feeds is None else row_feeds   # [R|K, F]
    valid = idx >= 0
    safe = jnp.where(valid, idx, 0)
    return valid, safe, jt.lineup_cap[safe], state.lineup_ha[safe], state.lineup_tot[safe]


def _row_view(jt: JaxTopology, state: HallState, rows):
    """Row-axis arrays, gathered at `rows` when given (compacted view).

    Every consumer computes per-row quantities elementwise, so a gathered
    view yields bitwise the values the full computation would produce at
    those rows — the compacted pod scan stays exactly equivalent to the
    full-row scan restricted to the subset."""
    if rows is None:
        return (jt.row_cap, state.row_load, jt.row_feeds, jt.row_nfeeds,
                jt.row_is_hd, jt.row_hall)
    return (jt.row_cap[rows], state.row_load[rows], jt.row_feeds[rows],
            jt.row_nfeeds[rows], jt.row_is_hd[rows], jt.row_hall[rows])


def _row_fits(jt: JaxTopology, state: HallState, dep: Deployment,
              n_in_row, rows=None) -> jax.Array:
    """Row/hall constraints outside the line-up power condition: the
    multi-resource row fit, the GPU→HD-row restriction, and the hall
    liquid plant.  Shared by both `row_feasible` paths — the kernel only
    owns the feed-gathered power math."""
    n = jnp.asarray(n_in_row, jnp.float32)
    d = rack_demand(dep.rack_kw, dep.is_gpu)          # [N_RES]
    D = n * d
    r_cap, r_load, _, _, r_is_hd, r_hall = _row_view(jt, state, rows)
    fits_row = jnp.all(r_load + D[None, :] <= r_cap + 1e-4, axis=-1)
    hd_ok = jnp.where(dep.is_gpu, r_is_hd, True)
    liq_ok = (state.hall_liq + D[LIQ])[r_hall] <= jt.hall_liq_cap[r_hall] + 1e-4
    return fits_row & hd_ok & liq_ok


def _kernel_feas_scores(jt: JaxTopology, state: HallState, dep: Deployment,
                        n_in_row, rows=None, interpret: bool = False,
                        block_r: int = DEFAULT_BLOCK_R):
    """Fused power-feasibility + variance scores via the Pallas kernel.

    Returns (kernel_feas [R|K] bool, var [R|K] f32).  `kernel_feas` is
    the power condition AND the row *power* fit — a superset of the full
    feasibility (`row_feasible` additionally checks the other resources,
    HD and liquid), so callers AND it with `_row_fits`.  `var` equals
    the jnp variance score bitwise at every kernel-feasible row and is
    `kernels.placement_score.kernel.BIG` elsewhere — rows the final
    feasibility mask sends to `_BIG` anyway."""
    n = jnp.asarray(n_in_row, jnp.float32)
    P = n * dep.rack_kw
    r_cap, r_load, r_feeds, r_nfeeds, _, _ = _row_view(jt, state, rows)
    return _kernel_score_rows(
        r_feeds, r_nfeeds, r_cap[:, POWER], state.lineup_ha,
        state.lineup_tot, jt.lineup_cap, r_load[:, POWER], P, jt.ha_frac,
        dep.tier == TIER_HA, jt.is_block, block_r=block_r,
        interpret=interpret)


def row_feasible(jt: JaxTopology, state: HallState, dep: Deployment,
                 n_in_row, rows=None, use_kernel: bool = False,
                 interpret: bool = False) -> jax.Array:
    """Feasibility mask over rows for placing `n_in_row` racks of `dep`'s
    SKU into a single row (Eq. 26 over the ancestor path).  With `rows`
    (int32 row-id subset) the mask covers only those rows — the
    HD-compacted pod scan's view.

    `use_kernel=True` (static) computes the line-up power condition with
    the fused Pallas kernel instead of the jnp gather; the result is
    bitwise identical (`tests/test_placement_kernel.py`).  `interpret`
    runs the kernel in Pallas interpret mode (CPU CI)."""
    extra = _row_fits(jt, state, dep, n_in_row, rows)
    if use_kernel:
        kfeas, _ = _kernel_feas_scores(jt, state, dep, n_in_row, rows,
                                       interpret=interpret)
        return extra & kfeas

    n = jnp.asarray(n_in_row, jnp.float32)
    P = n * dep.rack_kw
    _, _, r_feeds, r_nfeeds, _, _ = _row_view(jt, state, rows)
    valid, _, cap, ha_l, tot_l = _gather_feeds(jt, state, r_feeds)
    nf = jnp.maximum(r_nfeeds, 1).astype(jnp.float32)        # [R|K]
    share = P / nf
    # distributed HA: simultaneous failover headroom on every parent (Eq. 1)
    delta = P / jnp.maximum(nf - 1.0, 1.0)
    dist_ha = (ha_l + delta[:, None] <= jt.ha_frac * cap + 1e-4) & \
              (tot_l + share[:, None] <= cap + 1e-4)
    # distributed LA: may consume reserve up to full rating (Flex-style)
    dist_la = tot_l + share[:, None] <= cap + 1e-4
    # block: single primary feed at full rating
    block_ok = tot_l + P <= cap + 1e-4

    is_ha = dep.tier == TIER_HA
    dist_ok = jnp.where(is_ha, dist_ha, dist_la)
    per_feed = jnp.where(jt.is_block, block_ok, dist_ok)
    power_ok = jnp.all(per_feed | ~valid, axis=-1)

    return extra & power_ok


def row_scores(jt: JaxTopology, state: HallState, dep: Deployment,
               n_in_row, policy, key, rows=None, var=None,
               use_kernel: bool = False, interpret: bool = False
               ) -> jax.Array:
    """Per-row placement score (lower is better).  With `rows`, scores are
    the full-row scores gathered at the subset (the random draw is taken
    from the full-`R` grid and the round-robin distance keeps full-`R`
    row ids), so a compacted argmin matches the full argmin bitwise.

    `var` (optional, [R|K]) short-circuits the variance-score column —
    `place_in_row`'s kernel path passes the kernel's fused output so the
    feed gather runs once.  `use_kernel=True` computes it here via the
    kernel instead.  Either way the variance column carries the kernel's
    `BIG` mask at kernel-infeasible rows; callers mask scores by
    feasibility before the argmin (as `place_in_row` does), so selection
    is unaffected — standalone callers comparing raw scores against the
    jnp path should compare at feasible rows."""
    n = jnp.asarray(n_in_row, jnp.float32)
    P = n * dep.rack_kw
    R = jt.row_cap.shape[0]
    r_cap, r_load, r_feeds, r_nfeeds, r_is_hd, _ = _row_view(jt, state, rows)
    row_ids = jnp.arange(R) if rows is None else rows

    # Structural preference: non-GPU racks go to LD rows when possible.
    base = jnp.where(r_is_hd & ~dep.is_gpu, _LD_PREFERENCE, 0.0)

    rand = jax.random.uniform(key, (R,))
    rand = rand if rows is None else rand[rows]
    rr = jnp.mod(row_ids - state.rr_cursor, R).astype(jnp.float32) / R
    waste = (r_cap[:, POWER] - r_load[:, POWER] - P) / \
        jnp.maximum(r_cap[:, POWER], 1.0)

    if var is None and use_kernel:
        _, var = _kernel_feas_scores(jt, state, dep, n_in_row, rows,
                                     interpret=interpret)
    if var is None:
        valid, _, cap, ha_l, tot_l = _gather_feeds(jt, state, r_feeds)
        nf = jnp.maximum(r_nfeeds, 1).astype(jnp.float32)
        s = (P / nf)[:, None] / jnp.maximum(cap, 1.0)
        lhat = jnp.where(dep.tier == TIER_HA, ha_l, tot_l) / \
            jnp.maximum(cap, 1.0)
        var = jnp.sum(jnp.where(valid, 2.0 * lhat * s + s * s, 0.0), axis=-1)

    score = jnp.select(
        [policy == POLICY_RANDOM, policy == POLICY_ROUND_ROBIN,
         policy == POLICY_MIN_WASTE, policy == POLICY_VAR_MIN],
        [rand, rr, waste, var], var)
    return base + score


def _apply_to_row(jt: JaxTopology, state: HallState, dep: Deployment,
                  n_in_row, row) -> HallState:
    n = jnp.asarray(n_in_row, jnp.float32)
    d = rack_demand(dep.rack_kw, dep.is_gpu)
    P = n * dep.rack_kw
    row_load = state.row_load.at[row].add(n * d)
    feeds = jt.row_feeds[row]
    valid = feeds >= 0
    safe = jnp.where(valid, feeds, 0)
    nf = jnp.maximum(jt.row_nfeeds[row], 1).astype(jnp.float32)
    share = jnp.where(valid, P / nf, 0.0)
    is_ha = dep.tier == TIER_HA
    lineup_ha = state.lineup_ha.at[safe].add(jnp.where(is_ha, share, 0.0))
    lineup_tot = state.lineup_tot.at[safe].add(share)
    hall_liq = state.hall_liq.at[jt.row_hall[row]].add(n * d[LIQ])
    return HallState(row_load, lineup_ha, lineup_tot, hall_liq,
                     (row + 1).astype(jnp.int32))


def place_in_row(jt: JaxTopology, state: HallState, dep: Deployment,
                 n_in_row, policy, key, row_active, score_bias=None,
                 row_subset=None, use_kernel: bool = False,
                 interpret: bool = False):
    """Place `n_in_row` racks into the best feasible active row.
    Returns (state', ok, row).  `score_bias` (per-row, finite, and large
    relative to policy scores) expresses structural preferences among
    feasible rows — e.g. the fleet engine's keep-to-existing-halls rule.

    `row_subset` (int32 row ids) restricts the scan to those rows —
    feasibility, scores, `row_active` and `score_bias` are gathered at
    the subset and the winning slot maps back to its full row id.  When
    the subset provably contains every feasible row (the HD-compacted pod
    scan: GPU racks are HD-only), the result is bitwise identical to the
    full scan.

    `use_kernel=True` (static) runs ONE fused Pallas kernel call for the
    line-up power feasibility and the variance score instead of two jnp
    feed gathers; `interpret` runs it in Pallas interpret mode.  Chosen
    rows, state updates and `ok` are bitwise identical to the jnp path:
    kernel feasibility is AND-ed with the identical row/hall constraints,
    and the kernel's `BIG`-masked variance column only differs at rows
    the feasibility mask sends to `_BIG` anyway."""
    if use_kernel:
        kfeas, kvar = _kernel_feas_scores(jt, state, dep, n_in_row,
                                          rows=row_subset,
                                          interpret=interpret)
        feas = _row_fits(jt, state, dep, n_in_row, rows=row_subset) & kfeas
        score = row_scores(jt, state, dep, n_in_row, policy, key,
                           rows=row_subset, var=kvar)
    else:
        feas = row_feasible(jt, state, dep, n_in_row, rows=row_subset)
        score = row_scores(jt, state, dep, n_in_row, policy, key,
                           rows=row_subset)
    if row_subset is None:
        feas = feas & row_active
        if score_bias is not None:
            score = score + score_bias
    else:
        feas = feas & row_active[row_subset]
        if score_bias is not None:
            score = score + score_bias[row_subset]
    score = jnp.where(feas, score, _BIG)
    slot = jnp.argmin(score)
    ok = feas[slot]
    row = slot if row_subset is None else row_subset[slot]
    new_state = _apply_to_row(jt, state, dep, n_in_row, row)
    return _tree_where(ok, new_state, state), ok, jnp.where(ok, row, -1)


def place_cluster_in_row(jt: JaxTopology, state: HallState,
                         dep: Deployment, policy, key, row_active,
                         score_bias=None, use_kernel: bool = False,
                         interpret: bool = False):
    """`place_in_row` for a whole single-row cluster, with its result
    expanded to the `[MAX_POD_RACKS]` rows/counts registry convention
    `place` uses.  Returns (state', ok, rows, counts, row) — the shared
    cluster path of `place`, the fleet scan, and the single-hall
    simulator."""
    st, ok, row = place_in_row(jt, state, dep, dep.n_racks, policy, key,
                               row_active, score_bias=score_bias,
                               use_kernel=use_kernel, interpret=interpret)
    rows = jnp.full((MAX_POD_RACKS,), -1, jnp.int32).at[0].set(row)
    counts = jnp.zeros((MAX_POD_RACKS,)).at[0].set(
        jnp.where(ok, dep.n_racks.astype(jnp.float32), 0.0))
    return st, ok, rows, counts, row


def _place_pod(jt: JaxTopology, state: HallState, dep: Deployment,
               policy, key, row_active, max_racks: int = MAX_POD_RACKS,
               hd_scan: int | None = None, use_kernel: bool = False,
               interpret: bool = False):
    """Place a GPU pod rack-by-rack; all racks must land in the same power
    domain (cross-row cables, paper §4.1); atomic commit.

    `max_racks` is the static rack-scan length; callers that know the
    largest pod in their trace (the split-trace scans) pass it to skip
    dead scan steps — it must be ≥ every pod's `n_racks`.  The returned
    registry rows/counts are always `[MAX_POD_RACKS]`.

    `hd_scan` (static, ≥ the topology's HD-row count) restricts each
    rack's row search to the compacted HD view `jt.hd_index[:hd_scan]`:
    GPU pods are HD-only (`row_feasible`'s `hd_ok`), so skipping LD and
    padding rows is bitwise identical to the full scan while cutting the
    per-rack feasibility/score work to the HD share of the hall."""
    state0 = state
    subset = None if hd_scan is None else jt.hd_index[:hd_scan]

    def body(carry, i):
        st, all_ok, dom = carry
        k = jax.random.fold_in(key, i)
        active = row_active & ((dom < 0) | (jt.row_domain == dom))
        st2, ok, row = place_in_row(jt, st, dep, 1, policy, k, active,
                                    row_subset=subset,
                                    use_kernel=use_kernel,
                                    interpret=interpret)
        live = i < dep.n_racks
        st = _tree_where(live, st2, st)
        all_ok = all_ok & (ok | ~live)
        dom = jnp.where(live & ok & (dom < 0), jt.row_domain[jnp.maximum(row, 0)], dom)
        return (st, all_ok, dom), jnp.where(live, row, -1)

    (state_n, ok, _), rows = jax.lax.scan(
        body, (state, jnp.asarray(True), jnp.asarray(-1, jnp.int32)),
        jnp.arange(max_racks))
    if max_racks < MAX_POD_RACKS:
        rows = jnp.concatenate(
            [rows, jnp.full((MAX_POD_RACKS - max_racks,), -1, jnp.int32)])
    counts = jnp.where((rows >= 0) & ok, 1.0, 0.0)
    rows = jnp.where(ok, rows, -1)
    return _tree_where(ok, state_n, state0), ok, rows, counts


def place(jt: JaxTopology, state: HallState, dep: Deployment, policy, key,
          row_active=None, use_kernel: bool = False,
          interpret: bool = False):
    """Place one arrival (cluster or pod).

    Returns (state', ok, rows[MAX_POD_RACKS], counts[MAX_POD_RACKS]) where
    `rows`/`counts` record how many racks landed in each row (-1 padded) —
    the registry that harvesting / decommissioning consumes later.
    """
    if row_active is None:
        row_active = jnp.ones((jt.row_cap.shape[0],), bool)

    def cluster():
        return place_cluster_in_row(jt, state, dep, policy, key,
                                    row_active, use_kernel=use_kernel,
                                    interpret=interpret)[:4]

    return jax.lax.cond(
        dep.is_pod,
        lambda: _place_pod(jt, state, dep, policy, key, row_active,
                           use_kernel=use_kernel, interpret=interpret),
        cluster,
    )


def release_bulk(jt: JaxTopology, state: HallState, rows, counts, rack_kw,
                 is_gpu, tier, fraction) -> HallState:
    """Release `fraction` of the demand recorded by a batch of placement
    registries (harvest: fraction<1; decommission: fraction=1).

    rows/counts: [..., MAX_POD_RACKS] as returned by `place` (flattened ok),
    rack_kw/is_gpu/tier/fraction: per-event [...] arrays.
    """
    R = jt.row_cap.shape[0]
    rows = rows.reshape(-1)
    n = (counts * fraction[..., None]).reshape(-1)
    d = rack_demand(rack_kw, is_gpu)                       # [..., N_RES]
    d = jnp.broadcast_to(d[..., None, :],
                         counts.shape + (N_RES,)).reshape(-1, N_RES)
    ha = jnp.broadcast_to((tier == TIER_HA)[..., None],
                          counts.shape).reshape(-1)
    valid = rows >= 0
    safe_rows = jnp.where(valid, rows, 0)
    rel = jnp.where(valid[:, None], n[:, None] * d, 0.0)   # [Nflat, N_RES]

    row_rel = jax.ops.segment_sum(rel, safe_rows, R)       # [R, N_RES]
    row_rel_ha = jax.ops.segment_sum(rel[:, POWER] * ha, safe_rows, R)
    row_load = state.row_load - row_rel

    # distribute row power release back over feeds (balanced shares)
    nf = jnp.maximum(jt.row_nfeeds, 1).astype(jnp.float32)
    feeds_valid = jt.row_feeds >= 0
    safe_feeds = jnp.where(feeds_valid, jt.row_feeds, 0)
    X = jt.lineup_cap.shape[0]
    per_feed_tot = jnp.where(feeds_valid, (row_rel[:, POWER] / nf)[:, None], 0.0)
    per_feed_ha = jnp.where(feeds_valid, (row_rel_ha / nf)[:, None], 0.0)
    lineup_tot = state.lineup_tot - jax.ops.segment_sum(
        per_feed_tot.reshape(-1), safe_feeds.reshape(-1), X)
    lineup_ha = state.lineup_ha - jax.ops.segment_sum(
        per_feed_ha.reshape(-1), safe_feeds.reshape(-1), X)

    H = jt.hall_liq_cap.shape[0]
    hall_liq = state.hall_liq - jax.ops.segment_sum(
        row_rel[:, LIQ], jt.row_hall, H)
    return HallState(row_load, lineup_ha, lineup_tot, hall_liq,
                     state.rr_cursor)


def remove_from_row(jt: JaxTopology, state: HallState, rack_kw, is_gpu,
                    tier, row, n_racks=1, fraction=1.0) -> HallState:
    """Release `fraction` of `n_racks` racks' demand from `row` (harvest /
    decommission, paper §4.1)."""
    n = jnp.asarray(n_racks, jnp.float32) * jnp.asarray(fraction, jnp.float32)
    d = rack_demand(rack_kw, is_gpu)
    P = n * rack_kw
    row_load = state.row_load.at[row].add(-n * d)
    feeds = jt.row_feeds[row]
    valid = feeds >= 0
    safe = jnp.where(valid, feeds, 0)
    nf = jnp.maximum(jt.row_nfeeds[row], 1).astype(jnp.float32)
    share = jnp.where(valid, P / nf, 0.0)
    is_ha = jnp.asarray(tier, jnp.int32) == TIER_HA
    lineup_ha = state.lineup_ha.at[safe].add(-jnp.where(is_ha, share, 0.0))
    lineup_tot = state.lineup_tot.at[safe].add(-share)
    hall_liq = state.hall_liq.at[jt.row_hall[row]].add(-n * d[LIQ])
    return HallState(row_load, lineup_ha, lineup_tot, hall_liq, state.rr_cursor)


# ---------------------------------------------------------------------------
# Stranding metrics (paper §4.3).
# ---------------------------------------------------------------------------

def lineup_stranding(jt: JaxTopology, state: HallState) -> jax.Array:
    """Per-line-up unused fraction of *effective HA* capacity.  At
    saturation (placements failing) this is the stranded fraction."""
    eff = jt.ha_frac * jt.lineup_cap
    frac = (eff - state.lineup_ha) / jnp.maximum(eff, 1.0)
    return jnp.where(jt.lineup_is_active, jnp.clip(frac, 0.0, 1.0), 0.0)


def hall_stranding(jt: JaxTopology, state: HallState) -> jax.Array:
    """Per-hall unused fraction of effective HA capacity, shape [H].

    Hall membership comes from the topology's real line-up→hall map
    (`lineup_hall`), not an `arange // (X // H)` guess — the latter
    silently mis-bins line-ups whenever the line-up count is not an
    exact per-hall tiling.  In-repo `build_topology` grids always tile
    evenly, so this hardens hand-built / custom topologies (uneven hall
    sizes) rather than changing any pipeline result."""
    eff = jt.ha_frac * jt.lineup_cap * jt.lineup_is_active
    H = jt.hall_liq_cap.shape[0]
    eff_h = jax.ops.segment_sum(eff, jt.lineup_hall, H)
    load_h = jax.ops.segment_sum(state.lineup_ha * jt.lineup_is_active,
                                 jt.lineup_hall, H)
    return jnp.clip((eff_h - load_h) / jnp.maximum(eff_h, 1.0), 0.0, 1.0)


def deployed_kw(state: HallState) -> jax.Array:
    return jnp.sum(state.row_load[:, POWER])
