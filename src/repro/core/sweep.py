"""Batched fleet-sweep engine (paper §5–6 evaluation methodology).

The paper's deployable-capacity claims are joint over designs, arrival
scenarios, placement policies, and stochastic seeds — a grid of
lifecycle simulations, not one run.  This module evaluates such a grid
as ONE jitted + vmapped call: every configuration's topology is padded
to a common static shape (`hierarchy.build_topology` padding), traces
are padded to a common event count, and `fleet.simulate_lifecycle` is
`vmap`-ed over the whole `SweepAxes` batch.

    axes = SweepAxes.product(designs=[get_design("4N/3"), get_design("3+1")],
                             envs=[EnvelopeSpec(gpu_scenario=s)
                                   for s in ("med", "high")],
                             seeds=(0, 1))
    res = sweep(axes)                      # one compiled call, 8 configs
    res.p90_stranding[i, -1], res.effective_dpm[i], res.result(i) ...

On a multi-device host, `sharded_sweep` splits the same batch over the
named 2-D (config × trial) mesh (`repro.sharding.axes.sweep_mesh`) with
`shard_map`, so each device simulates only its own slab of
configurations; `chunk_size` streams giant grids through one compiled
executable with donated input buffers, and `exact_quantiles=False`
swaps the per-config `[M, H]` stranding history for the O(1)-memory
streaming histogram quantiles (`repro.core.quantiles`):

    res = sharded_sweep(axes)              # == sweep(axes), D-way parallel
    res = sharded_sweep(axes, mesh_shape=(2, 2), chunk_size=256,
                        exact_quantiles=False)   # planet-scale settings

The configuration axis is embarrassingly parallel (no cross-config
collectives), so sharded and single-device results agree to float
tolerance; on one device `sharded_sweep` is a passthrough to `sweep`.
Simulated multi-device CPU runs use
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import functools
import itertools
import warnings
from dataclasses import dataclass, field
from types import SimpleNamespace
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from . import cost, placement as pl, throughput as tp
from .arrivals import EnvelopeSpec, Trace, generate_fleet_trace
from .fleet import (FleetConfig, FleetResult, FleetTrace, _auto_halls,
                    _event_windows, _month_e_max, _pod_scan_len,
                    make_fleet_result, simulate_lifecycle)
from .hierarchy import DesignSpec, SweepValidationError, build_topology
from .placement import DEFAULT_POLICY, MAX_POD_RACKS, POLICY_NAMES
from repro.sharding import axes as shax


def _broadcast(seq, B, name):
    seq = list(seq)
    if len(seq) == 1:
        seq = seq * B
    if len(seq) != B:
        raise SweepValidationError(
            name, f"has length {len(seq)}, expected {B} (the batch size) "
            f"or 1 (broadcast)")
    return seq


@dataclass
class SweepAxes:
    """The configuration batch the engine vmaps over.

    Four aligned per-configuration lists of equal length ``B`` (the batch
    size): configuration ``i`` is ``(designs[i], envs[i], policies[i],
    seeds[i])``.  Length-1 lists broadcast to ``B`` in ``__post_init__``,
    so ``SweepAxes.zip(designs=[d], envs=envs_list)`` reuses one design
    across every envelope.

    Construct with:

    * `SweepAxes.zip` — aligned sequences, one entry per configuration.
    * `SweepAxes.product` — the full cross product (designs-major
      ordering: the seed axis varies fastest, designs slowest).

    `config(i)` recovers the i-th configuration as a sequential
    `fleet.FleetConfig`, which is how the equivalence tests compare a
    sweep against `fleet.run_fleet`.

    `tags` is an optional aligned list of free-form per-configuration
    labels (scenario generators use `"family:label"` — see
    `repro.core.scenarios`); it broadcasts like the other axes and rides
    along purely for reporting (`SweepResult.tags`).
    """
    designs: List[DesignSpec]
    envs: List[EnvelopeSpec]
    policies: List[int]
    seeds: List[int]
    tags: List[str] = field(default_factory=lambda: [""])

    def __len__(self):
        return len(self.designs)

    def __post_init__(self):
        B = max(len(self.designs), len(self.envs), len(self.policies),
                len(self.seeds), len(self.tags))
        self.designs = _broadcast(self.designs, B, "designs")
        self.envs = _broadcast(self.envs, B, "envs")
        self.policies = [int(p) for p in _broadcast(self.policies, B,
                                                    "policies")]
        self.seeds = [int(s) for s in _broadcast(self.seeds, B, "seeds")]
        self.tags = [str(t) for t in _broadcast(self.tags, B, "tags")]

    @staticmethod
    def zip(designs, envs, policies=(DEFAULT_POLICY,), seeds=(0,),
            tags=("",)) -> "SweepAxes":
        """Aligned per-configuration sequences (length-1 broadcasts)."""
        return SweepAxes(list(designs), list(envs), list(policies),
                         list(seeds), list(tags))

    @staticmethod
    def product(designs: Sequence[DesignSpec], envs: Sequence[EnvelopeSpec],
                policies: Sequence[int] = (DEFAULT_POLICY,),
                seeds: Sequence[int] = (0,),
                env_tags: Sequence[str] | None = None) -> "SweepAxes":
        """Full grid, designs-major ordering.  `env_tags` (aligned with
        `envs`) labels each envelope; the tag follows its envelope
        through the cross product."""
        env_tags = list(env_tags) if env_tags is not None else [""] * len(envs)
        if len(env_tags) != len(envs):
            raise ValueError(f"env_tags has length {len(env_tags)}, "
                             f"expected {len(envs)}")
        combos = list(itertools.product(designs, zip(envs, env_tags),
                                        policies, seeds))
        return SweepAxes([c[0] for c in combos], [c[1][0] for c in combos],
                         [c[2] for c in combos], [c[3] for c in combos],
                         [c[1][1] for c in combos])

    def config(self, i: int, harvest: bool = True,
               mature_months: int = 12) -> FleetConfig:
        """The i-th configuration as a sequential `FleetConfig`."""
        return FleetConfig(self.designs[i], self.envs[i],
                           policy=self.policies[i], seed=self.seeds[i],
                           harvest=harvest, mature_months=mature_months)

    def validate(self) -> "SweepAxes":
        """Raise `SweepValidationError` before any compile time is spent.

        Checks every distinct design and envelope (`DesignSpec.validate`
        / `EnvelopeSpec.validate`), policy ids, and horizon homogeneity.
        Distinct = by object identity, so a 10⁴-config grid sharing a
        handful of spec objects validates in microseconds."""
        if len(self) == 0:
            raise SweepValidationError(
                "designs", "empty sweep: zero configurations")
        seen: set = set()
        for d in self.designs:
            if id(d) not in seen:
                seen.add(id(d))
                d.validate()
        for e in self.envs:
            if id(e) not in seen:
                seen.add(id(e))
                e.validate()
        for i, p in enumerate(self.policies):
            if not 0 <= p < len(POLICY_NAMES):
                raise SweepValidationError(
                    "policies", f"policies[{i}] = {p} outside "
                    f"[0, {len(POLICY_NAMES)}); have {POLICY_NAMES}")
        horizons = {(e.start_year, e.end_year) for e in self.envs}
        if len(horizons) > 1:
            raise SweepValidationError(
                "envs", f"envelopes span different horizons: "
                f"{sorted(horizons)}; the lifecycle scan needs one common "
                f"month count")
        return self


@dataclass
class SweepResult:
    """Per-configuration metrics, leading axis = configuration."""
    axes: SweepAxes
    months: np.ndarray             # [M]
    halls_active: np.ndarray       # [B, M]
    deployed_mw: np.ndarray        # [B, M]
    p50_stranding: np.ndarray      # [B, M]
    p90_stranding: np.ndarray      # [B, M]
    final_hall_stranding: np.ndarray    # [B, H_max] (use n_halls_built)
    final_lineup_stranding: np.ndarray  # [B, X_tot]
    lineup_is_active: np.ndarray   # [B, X_tot]
    lineups_per_hall: int          # common padded per-hall line-up count
    n_halls_built: np.ndarray      # [B] int
    final_deployed_mw: np.ndarray  # [B]
    placed_fraction: np.ndarray    # [B]
    initial_dpm: np.ndarray        # [B] $/MW at commissioning
    effective_dpm: np.ndarray      # [B] lifecycle-effective $/MW
    total_capex: np.ndarray        # [B] $
    # --- metric stage (paper §5.4/§6.6: $/performance, not installed MW) ---
    provisioned_mw: np.ndarray = None   # [B] halls built × HA nameplate
    model_names: List[str] = field(default_factory=list)   # [Mdl]
    delivered_tps: np.ndarray = None         # [B, Mdl] fleet tokens/s
    tps_per_provisioned_w: np.ndarray = None  # [B, Mdl] tokens/s per built W
    dollars_per_tps: np.ndarray = None       # [B, Mdl] capex / delivered TPS
    # --- resilient execution (repro.core.resilience) ---
    report: object = None          # RunReport when run via resilient_sweep

    def __len__(self):
        return len(self.axes)

    @property
    def tags(self) -> List[str]:
        """Per-configuration labels (see `SweepAxes.tags`)."""
        return self.axes.tags

    def result(self, i: int) -> FleetResult:
        """Unpack configuration `i` into a sequential-style FleetResult."""
        out = SimpleNamespace(  # per-configuration SimOutputs view
            halls_active=self.halls_active[i],
            deployed_kw=self.deployed_mw[i] * 1e3,
            p50_stranding=self.p50_stranding[i],
            p90_stranding=self.p90_stranding[i],
            final_hall_stranding=self.final_hall_stranding[i],
            final_lineup_stranding=self.final_lineup_stranding[i],
            n_halls_built=self.n_halls_built[i],
            final_deployed_kw=self.final_deployed_mw[i] * 1e3,
            placed_fraction=self.placed_fraction[i])
        return make_fleet_result(out, len(self.months),
                                 self.lineups_per_hall,
                                 self.lineup_is_active[i],
                                 self.axes.designs[i], self.axes.envs[i])

    def results(self) -> List[FleetResult]:
        return [self.result(i) for i in range(len(self))]


@functools.partial(jax.jit,
                   static_argnames=("harvest", "mature_months", "with_pods",
                                    "legacy_pod_cond", "pod_scan_len",
                                    "hd_scan", "use_kernel",
                                    "kernel_interpret", "exact_quantiles",
                                    "quantile_bins"))
def _sweep_jit(jt, ft, idx, valid, idx_pod, valid_pod, policy, seed, h_cap,
               n_real, harvest, mature_months, with_pods,
               legacy_pod_cond=False, pod_scan_len=MAX_POD_RACKS,
               hd_scan=None, use_kernel=False, kernel_interpret=False,
               exact_quantiles=True, quantile_bins=None):
    fn = functools.partial(simulate_lifecycle, harvest=harvest,
                           mature_months=mature_months, with_pods=with_pods,
                           legacy_pod_cond=legacy_pod_cond,
                           pod_scan_len=pod_scan_len, hd_scan=hd_scan,
                           use_kernel=use_kernel,
                           kernel_interpret=kernel_interpret,
                           exact_quantiles=exact_quantiles,
                           quantile_bins=quantile_bins)
    return jax.vmap(fn)(jt, ft, idx, valid, idx_pod, valid_pod, policy,
                        seed, h_cap, n_real)


@functools.partial(jax.jit,
                   static_argnames=("harvest", "mature_months", "with_pods",
                                    "pod_scan_len", "hd_scan", "use_kernel",
                                    "kernel_interpret", "exact_quantiles",
                                    "quantile_bins", "mesh"),
                   donate_argnums=tuple(range(10)))
def _sharded_sweep_jit(jt, ft, idx, valid, idx_pod, valid_pod, policy, seed,
                       h_cap, n_real, harvest, mature_months, with_pods,
                       pod_scan_len, hd_scan, use_kernel, kernel_interpret,
                       exact_quantiles, quantile_bins, mesh):
    """`_sweep_jit` with the flat configuration batch split over `mesh`
    (2-D config × trial; the batch product-shards over both axes via
    `batch_spec`, so a (D, 1) mesh reproduces the historical 1-D
    layout): each device vmaps only its own B/(dc·dt) slab.  No
    collectives — configurations are independent — so out_specs keep
    everything batch-sharded.  All ten operand buffers are donated: a
    chunk's inputs die the moment its dispatch is queued, which is what
    keeps per-device memory flat while `sharded_sweep` streams chunks."""
    fn = functools.partial(simulate_lifecycle, harvest=harvest,
                           mature_months=mature_months, with_pods=with_pods,
                           pod_scan_len=pod_scan_len, hd_scan=hd_scan,
                           use_kernel=use_kernel,
                           kernel_interpret=kernel_interpret,
                           exact_quantiles=exact_quantiles,
                           quantile_bins=quantile_bins)
    spec = shax.batch_spec()
    sharded = shax.shard_map(jax.vmap(fn), mesh=mesh,
                             in_specs=(spec,) * 10, out_specs=spec,
                             check_vma=False)
    return sharded(jt, ft, idx, valid, idx_pod, valid_pod, policy, seed,
                   h_cap, n_real)


def _prepare(axes: SweepAxes, n_halls_max: int,
             traces: Sequence[Trace] | None,
             legacy_pod_cond: bool = False):
    """Host-side batch assembly shared by `sweep` and `sharded_sweep`.

    Pads every configuration to common static shapes, **bucketed** so
    sweeps over new seeds/scenarios reuse the compiled executable
    (jit-cache hit):

    * hall cap `H_max` — max auto-sized hall count, bucketed to 4;
    * rows/line-ups per hall — max over designs (zero-capacity padding
      rows are never feasible, padded line-ups are inactive);
    * trace events `E_max` — max trace length, bucketed to 64
      (padding events arrive at month `M`, beyond the horizon);
    * per-month event windows — max monthly cluster count bucketed to 4
      and, for pod traces on the split-trace path, max monthly pod
      count bucketed to 2 (pod scan steps are ~8× a cluster step, so
      the pod window is padded more tightly).

    Returns `(args, months, topos, X_pad, with_pods, pod_scan_len,
    hd_scan)` where `args` is the 10-tuple of stacked device inputs for
    `simulate_lifecycle` (leading axis = configuration), `topos` the
    per-configuration padded host topologies, and the trailing statics
    trim the pod rack scan / compacted HD row view.
    `legacy_pod_cond=True` windows all events together for the
    pre-split reference path (see `simulate_lifecycle`).
    """
    axes.validate()          # precise SweepValidationErrors, pre-compile
    B = len(axes)
    months = axes.envs[0].n_months

    if traces is None:
        traces = [generate_fleet_trace(e, s)
                  for e, s in zip(axes.envs, axes.seeds)]
    if len(traces) != B:
        raise SweepValidationError(
            "traces", f"need one trace per configuration: got "
            f"{len(traces)} traces for {B} configurations")

    def bucket(n, q):
        return int(np.ceil(max(n, 1) / q) * q)

    h_caps = [n_halls_max or _auto_halls(d, e)
              for d, e in zip(axes.designs, axes.envs)]
    H_max = bucket(max(h_caps), 4)
    R_pad = max(d.n_rows for d in axes.designs)
    X_pad = max(d.n_lineups for d in axes.designs)
    topos = [build_topology(d, H_max, rows_per_hall=R_pad,
                            lineups_per_hall=X_pad) for d in axes.designs]
    jt = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[pl.jax_topology(t) for t in topos])

    E_max = bucket(max(len(t) for t in traces), 64)
    ft = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[FleetTrace.from_trace(t, pad_to=E_max,
                                              pad_month=months)
                        for t in traces])
    with_pods = any(bool(np.asarray(t.is_pod).any()) for t in traces)
    split = with_pods and not legacy_pod_cond
    pod_sel = [np.asarray(t.is_pod) for t in traces]
    e_max = bucket(max(_month_e_max(t, months,
                                    select=~p if split else None)
                       for t, p in zip(traces, pod_sel)), 4)
    # pod windows stay exact (no bucket): a pod scan step costs ~16
    # cluster steps (two 8-rack `_place_pod` scans), so one padded pod
    # slot per month would erase most of the split-trace win; monthly
    # pod counts are small and stable within a study, so the jit cache
    # still carries across same-scale grids.
    ep_max = (max(_month_e_max(t, months, select=p)
                  for t, p in zip(traces, pod_sel)) if split else 1)
    windows = [_event_windows(t, months, split, e_max=e_max, ep_max=ep_max,
                              modulo=E_max) for t in traces]
    idx = jnp.asarray(np.stack([w[0] for w in windows]))
    valid = jnp.asarray(np.stack([w[1] for w in windows]))
    idx_pod = jnp.asarray(np.stack([w[2] for w in windows]))
    valid_pod = jnp.asarray(np.stack([w[3] for w in windows]))

    args = (jt, ft, idx, valid, idx_pod, valid_pod,
            jnp.asarray(axes.policies, jnp.int32),
            jnp.asarray(axes.seeds, jnp.int32),
            jnp.asarray(h_caps, jnp.int32),
            jnp.asarray([len(t) for t in traces], jnp.int32))
    hd_scan = max(t.n_hd_rows for t in topos)
    return args, months, topos, X_pad, with_pods, _pod_scan_len(traces), \
        hd_scan


def serving_tpw_rows(envs: Sequence[EnvelopeSpec],
                     models: Sequence[tp.MoEModel],
                     metric_year: int | None = None) -> np.ndarray:
    """[B, Mdl] serving tokens/s-per-watt rows for a batch of envelopes.

    Each envelope implies one serving deployment (`tp.serving_deployment`
    at `metric_year`, default its `end_year`, at its placement quantum);
    batches share few distinct deployments, so rows are gathered from ONE
    jitted `tps_per_watt_grid` over the unique set.  Shared with
    `mc_sweep` and `payoff`."""
    keys = [(int(metric_year or e.end_year), e.gpu_scenario,
             max(int(e.pod_racks), 1),
             bool(e.pod_scale_arch or e.pod_racks > 1)) for e in envs]
    uniq = sorted(set(keys))
    deps = [tp.serving_deployment(*k) for k in uniq]
    grid = np.asarray(tp.tps_per_watt_grid(models, deps))
    row = {k: grid[i] for i, k in enumerate(uniq)}
    return np.stack([row[k] for k in keys])


def gpu_power_share(env: EnvelopeSpec) -> float:
    """Fraction of deployed MW that is GPU serving capacity (the rest is
    general compute / storage and delivers no tokens)."""
    total = env.gpu_gw + env.compute_gw + env.storage_gw
    return env.gpu_gw / total if total > 0 else 0.0


def _metric_stage(axes: SweepAxes, models, metric_year,
                  deployed_mw: np.ndarray, provisioned_mw: np.ndarray,
                  capex: np.ndarray):
    """Batched throughput/cost columns over final deployed capacity.

    `deployed_mw`/`provisioned_mw`/`capex` are [B]; returns
    (model_names, delivered_tps, tps_per_provisioned_w, dollars_per_tps)
    each [B, Mdl].  NaN marks undefined ratios (nothing built or nothing
    delivered), never inf."""
    models = (tp.MODEL_SUITE if models is None
              else tuple(tp.resolve_model(m) for m in models))
    B = len(axes)
    if not models:
        empty = np.zeros((B, 0))
        return [], empty, empty.copy(), empty.copy()
    tpw = serving_tpw_rows(axes.envs, models, metric_year)
    share = np.array([gpu_power_share(e) for e in axes.envs])
    delivered = tpw * (deployed_mw * 1e6 * share)[:, None]
    with np.errstate(divide="ignore", invalid="ignore"):
        tps_per_pw = np.where(provisioned_mw[:, None] > 0,
                              delivered / (provisioned_mw[:, None] * 1e6),
                              np.nan)
        dpt = np.where(delivered > 0, capex[:, None] / delivered, np.nan)
    return [m.name for m in models], delivered, tps_per_pw, dpt


def _finalize(out, axes: SweepAxes, months: int, topos, X_pad: int,
              mature_months: int, models=None,
              metric_year: int | None = None) -> SweepResult:
    """Host-side unpack of batched `SimOutputs` + cost model into a
    `SweepResult` (shared by `sweep` and `sharded_sweep`)."""
    n_built = np.asarray(out.n_halls_built).astype(int)
    deployed_mw = np.asarray(out.final_deployed_kw) / 1e3
    initial = np.array([cost.initial_dollars_per_mw(d)
                        for d in axes.designs])
    effective = np.array([
        cost.effective_dollars_per_mw(d, int(n), float(mw))
        for d, n, mw in zip(axes.designs, n_built, deployed_mw)])
    capex = np.array([int(n) * cost.hall_capex(d)
                      for d, n in zip(axes.designs, n_built)])
    provisioned = np.array([int(n) * d.ha_capacity_kw / 1e3
                            for d, n in zip(axes.designs, n_built)])
    names, delivered, tps_per_pw, dpt = _metric_stage(
        axes, models, metric_year, deployed_mw, provisioned, capex)
    return SweepResult(
        axes=axes,
        months=np.arange(months),
        halls_active=np.asarray(out.halls_active),
        deployed_mw=np.asarray(out.deployed_kw) / 1e3,
        p50_stranding=np.asarray(out.p50_stranding),
        p90_stranding=np.asarray(out.p90_stranding),
        final_hall_stranding=np.asarray(out.final_hall_stranding),
        final_lineup_stranding=np.asarray(out.final_lineup_stranding),
        lineup_is_active=np.stack([np.asarray(t.lineup_is_active)
                                   for t in topos]),
        lineups_per_hall=X_pad,
        n_halls_built=n_built,
        final_deployed_mw=deployed_mw,
        placed_fraction=np.asarray(out.placed_fraction),
        initial_dpm=initial,
        effective_dpm=effective,
        total_capex=capex,
        provisioned_mw=provisioned,
        model_names=names,
        delivered_tps=delivered,
        tps_per_provisioned_w=tps_per_pw,
        dollars_per_tps=dpt,
    )


def sweep(axes: SweepAxes, harvest: bool = True, mature_months: int = 12,
          n_halls_max: int = 0,
          traces: Sequence[Trace] | None = None,
          legacy_pod_cond: bool = False, models=None,
          metric_year: int | None = None,
          use_kernel: bool | None = None,
          kernel_interpret: bool = False,
          exact_quantiles: bool = True,
          quantile_bins: int | None = None) -> SweepResult:
    """Evaluate every configuration in `axes` in one compiled call.

    All envelopes must share the same buildout horizon (the scan length).
    Returns a `SweepResult`; `result(i)` recovers the `FleetResult` a
    sequential `run_fleet(axes.config(i))` would produce (identical up to
    float-padding noise for score-based policies).

    Padding is provably inert for the exact single-configuration
    semantics: padded rows have zero capacity (never feasible), padded
    line-ups are inactive (excluded from stranding stats), and padded
    trace events arrive after the simulated horizon.  Pod-free traces
    compile the cheap biased-placement path: instead of the
    try-then-open-a-hall `lax.cond` retry (which vmap evaluates on both
    branches), a single `place_in_row` attempt with `score_bias` added to
    rows of the not-yet-open hall picks the same row either way — a
    failed first attempt means no existing-hall row was feasible, so the
    biased argmin lands in the new hall exactly when the retry would.
    Pod traces compile the split-trace scan: each month's pod events run
    through the genuine attempt/retry pod path and its cluster events
    through the biased attempt, so neither pays for the other's branch
    (see `fleet.simulate_lifecycle`).

    Args:
        axes: the configuration batch (see `SweepAxes`).
        harvest: harvest one-year-old racks (static across the batch).
        mature_months: hall age before it enters tail stranding stats.
        n_halls_max: static hall cap; 0 auto-sizes per configuration.
        traces: optional pre-generated per-configuration arrival traces
            (defaults to `generate_fleet_trace(envs[i], seeds[i])`).
        legacy_pod_cond: compile the pre-split per-event
            `lax.cond(is_pod, …)` + retry path instead (reference for
            `pod_sweep_speedup` and the split-equivalence tests; results
            are identical).
        models: Table 2 models (objects or names) for the $/performance
            metric stage (default `throughput.MODEL_SUITE`; `()` skips
            the stage).
        metric_year: serving-deployment year for the metric stage
            (default: each envelope's `end_year`).
        use_kernel: route placement scoring through the fused Pallas
            kernel (static; bitwise-identical results).  `None` = backend
            default (`placement.default_use_kernel`: TPU on, CPU off).
        kernel_interpret: run the kernel in Pallas interpret mode (CPU
            CI fallback; only meaningful with the kernel path on).
        exact_quantiles: `True` (default) keeps the exact post-hoc
            p50/p90 reduction over each configuration's `[M, H]`
            stranding history; `False` compiles the O(1)-memory
            streaming histogram path (error ≤ `1 / quantile_bins`; see
            `fleet.simulate_lifecycle`) — the right choice for giant
            grids where the per-config history dominates memory.
        quantile_bins: streaming-histogram resolution (default
            `quantiles.DEFAULT_BINS` = 512); ignored when exact.
    """
    args, months, topos, X_pad, with_pods, pod_len, hd_scan = _prepare(
        axes, n_halls_max, traces, legacy_pod_cond)
    out = _sweep_jit(*args, harvest=harvest, mature_months=mature_months,
                     with_pods=with_pods, legacy_pod_cond=legacy_pod_cond,
                     pod_scan_len=pod_len, hd_scan=hd_scan,
                     use_kernel=pl.resolve_use_kernel(use_kernel),
                     kernel_interpret=kernel_interpret,
                     exact_quantiles=exact_quantiles,
                     quantile_bins=quantile_bins)
    return _finalize(out, axes, months, topos, X_pad, mature_months,
                     models=models, metric_year=metric_year)


def sharded_sweep(axes: SweepAxes, harvest: bool = True,
                  mature_months: int = 12, n_halls_max: int = 0,
                  traces: Sequence[Trace] | None = None,
                  devices: Sequence[jax.Device] | None = None,
                  models=None, metric_year: int | None = None,
                  use_kernel: bool | None = None,
                  kernel_interpret: bool = False,
                  exact_quantiles: bool = True,
                  quantile_bins: int | None = None,
                  mesh_shape: tuple[int, int] | None = None,
                  chunk_size: int | None = None) -> SweepResult:
    """`sweep`, with the configuration batch sharded over a device mesh.

    The batch is split over the named 2-D (config × trial) mesh of
    `repro.sharding.axes.sweep_mesh` via `shard_map`: the flat
    configuration axis product-shards over BOTH mesh axes
    (`batch_spec`), so each device receives only its own slab of padded
    topologies and traces (`jax.device_put` with a batch-sharded
    `NamedSharding`, so slabs land on their device up front rather than
    being replicated) and vmaps `simulate_lifecycle` over the B/(dc·dt)
    configurations it owns.  The default `mesh_shape` is `(D, 1)` —
    bitwise the historical 1-D `CONFIG_AXIS` layout — and any `(dc, dt)`
    with `dc·dt = D` places the same slabs on the same device order.
    Configurations are independent, so results match single-device
    `sweep` to float tolerance.

    Grids whose size does not divide the device count are padded by
    replicating configuration 0 up to the next multiple of D; the
    replicas are dropped before `SweepResult` assembly, so remainder
    grids return exactly `B` configurations.

    `chunk_size` streams the batch through the compiled executable in
    fixed-size chunks instead of one dispatch: every chunk shares one
    executable (identical static shapes), dispatches asynchronously
    (JAX queues the next chunk while the previous computes), and donates
    its input buffers (`donate_argnums` on `_sharded_sweep_jit`), so
    per-device live memory is bounded by one chunk — flat in grid size.
    This is how `giant_grid` sweeps ≥10⁴ configurations.

    With one device (or a length-1 batch) this is a passthrough to
    `sweep` — unless `chunk_size` is set, which engages the chunked
    streaming dispatch on a trivial 1×1 mesh (bounded live memory is
    useful without parallelism).  To exercise the sharded path on a
    single-CPU host, set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    first jax import.

    Args: as `sweep`, plus
        devices: devices to shard over (default `jax.devices()`).
        mesh_shape: (config, trial) mesh extents; must multiply out to
            the device count (default `(D, 1)`).
        chunk_size: configurations per dispatch (rounded up to a
            multiple of the device count; default: the whole batch).
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    # chunked dispatch is meaningful even on one device (live memory
    # bounded by one chunk), so only passthrough when it isn't requested
    if (len(devs) <= 1 and chunk_size is None) or len(axes) == 1:
        return sweep(axes, harvest=harvest, mature_months=mature_months,
                     n_halls_max=n_halls_max, traces=traces, models=models,
                     metric_year=metric_year, use_kernel=use_kernel,
                     kernel_interpret=kernel_interpret,
                     exact_quantiles=exact_quantiles,
                     quantile_bins=quantile_bins)

    args, months, topos, X_pad, with_pods, pod_len, hd_scan = _prepare(
        axes, n_halls_max, traces)
    B, D = len(axes), len(devs)
    C = -(-B // D) * D if chunk_size is None \
        else max(-(-int(chunk_size) // D) * D, D)
    B_pad = -(-B // C) * C
    if B_pad != B:
        def pad(x):
            fill = jnp.broadcast_to(x[:1], (B_pad - B,) + x.shape[1:])
            return jnp.concatenate([x, fill])
        args = jax.tree.map(pad, args)

    mesh = shax.sweep_mesh(devs, mesh_shape)
    sharding = NamedSharding(mesh, shax.batch_spec())
    kw = dict(harvest=harvest, mature_months=mature_months,
              with_pods=with_pods, pod_scan_len=pod_len, hd_scan=hd_scan,
              use_kernel=pl.resolve_use_kernel(use_kernel),
              kernel_interpret=kernel_interpret,
              exact_quantiles=exact_quantiles,
              quantile_bins=quantile_bins, mesh=mesh)
    outs = []
    with warnings.catch_warnings():
        # int topology/trace buffers can never alias the f32 output
        # curves; XLA's per-buffer "donated but not usable" note is
        # expected here, and the usable donations still land
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        for s in range(0, B_pad, C):
            chunk = jax.device_put(
                jax.tree.map(lambda x: x[s:s + C], args), sharding)
            outs.append(_sharded_sweep_jit(*chunk, **kw))
    out = outs[0] if len(outs) == 1 else \
        jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)
    if B_pad != B:
        out = jax.tree.map(lambda x: x[:B], out)
    return _finalize(out, axes, months, topos, X_pad, mature_months,
                     models=models, metric_year=metric_year)
