"""Batched fleet-sweep engine (paper §5–6 evaluation methodology).

The paper's deployable-capacity claims are joint over designs, arrival
scenarios, placement policies, and stochastic seeds — a grid of
lifecycle simulations, not one run.  This module evaluates such a grid
as ONE jitted + vmapped call: every configuration's topology is padded
to a common static shape (`hierarchy.build_topology` padding), traces
are padded to a common event count, and `fleet.simulate_lifecycle` is
`vmap`-ed over the whole `SweepAxes` batch.

    axes = SweepAxes.product(designs=[get_design("4N/3"), get_design("3+1")],
                             envs=[EnvelopeSpec(gpu_scenario=s)
                                   for s in ("med", "high")],
                             seeds=(0, 1))
    res = sweep(axes)                      # one compiled call, 8 configs
    res.p90_stranding[i, -1], res.effective_dpm[i], res.result(i) ...
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass
from types import SimpleNamespace
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import cost, placement as pl
from .arrivals import EnvelopeSpec, Trace, generate_fleet_trace
from .fleet import (FleetConfig, FleetResult, FleetTrace, _auto_halls,
                    _month_e_max, _month_slices, make_fleet_result,
                    simulate_lifecycle)
from .hierarchy import DesignSpec, build_topology
from .placement import DEFAULT_POLICY


def _broadcast(seq, B, name):
    seq = list(seq)
    if len(seq) == 1:
        seq = seq * B
    if len(seq) != B:
        raise ValueError(f"{name} has length {len(seq)}, expected {B} or 1")
    return seq


@dataclass
class SweepAxes:
    """One entry per configuration: the batch the engine vmaps over."""
    designs: List[DesignSpec]
    envs: List[EnvelopeSpec]
    policies: List[int]
    seeds: List[int]

    def __len__(self):
        return len(self.designs)

    def __post_init__(self):
        B = max(len(self.designs), len(self.envs), len(self.policies),
                len(self.seeds))
        self.designs = _broadcast(self.designs, B, "designs")
        self.envs = _broadcast(self.envs, B, "envs")
        self.policies = [int(p) for p in _broadcast(self.policies, B,
                                                    "policies")]
        self.seeds = [int(s) for s in _broadcast(self.seeds, B, "seeds")]

    @staticmethod
    def zip(designs, envs, policies=(DEFAULT_POLICY,), seeds=(0,)
            ) -> "SweepAxes":
        """Aligned per-configuration sequences (length-1 broadcasts)."""
        return SweepAxes(list(designs), list(envs), list(policies),
                         list(seeds))

    @staticmethod
    def product(designs: Sequence[DesignSpec], envs: Sequence[EnvelopeSpec],
                policies: Sequence[int] = (DEFAULT_POLICY,),
                seeds: Sequence[int] = (0,)) -> "SweepAxes":
        """Full grid, designs-major ordering."""
        combos = list(itertools.product(designs, envs, policies, seeds))
        return SweepAxes([c[0] for c in combos], [c[1] for c in combos],
                         [c[2] for c in combos], [c[3] for c in combos])

    def config(self, i: int, harvest: bool = True,
               mature_months: int = 12) -> FleetConfig:
        """The i-th configuration as a sequential `FleetConfig`."""
        return FleetConfig(self.designs[i], self.envs[i],
                           policy=self.policies[i], seed=self.seeds[i],
                           harvest=harvest, mature_months=mature_months)


@dataclass
class SweepResult:
    """Per-configuration metrics, leading axis = configuration."""
    axes: SweepAxes
    months: np.ndarray             # [M]
    halls_active: np.ndarray       # [B, M]
    deployed_mw: np.ndarray        # [B, M]
    p50_stranding: np.ndarray      # [B, M]
    p90_stranding: np.ndarray      # [B, M]
    final_hall_stranding: np.ndarray    # [B, H_max] (use n_halls_built)
    final_lineup_stranding: np.ndarray  # [B, X_tot]
    lineup_is_active: np.ndarray   # [B, X_tot]
    lineups_per_hall: int          # common padded per-hall line-up count
    n_halls_built: np.ndarray      # [B] int
    final_deployed_mw: np.ndarray  # [B]
    placed_fraction: np.ndarray    # [B]
    initial_dpm: np.ndarray        # [B] $/MW at commissioning
    effective_dpm: np.ndarray      # [B] lifecycle-effective $/MW
    total_capex: np.ndarray        # [B] $

    def __len__(self):
        return len(self.axes)

    def result(self, i: int) -> FleetResult:
        """Unpack configuration `i` into a sequential-style FleetResult."""
        out = SimpleNamespace(  # per-configuration SimOutputs view
            halls_active=self.halls_active[i],
            deployed_kw=self.deployed_mw[i] * 1e3,
            p50_stranding=self.p50_stranding[i],
            p90_stranding=self.p90_stranding[i],
            final_hall_stranding=self.final_hall_stranding[i],
            final_lineup_stranding=self.final_lineup_stranding[i],
            n_halls_built=self.n_halls_built[i],
            final_deployed_kw=self.final_deployed_mw[i] * 1e3,
            placed_fraction=self.placed_fraction[i])
        return make_fleet_result(out, len(self.months),
                                 self.lineups_per_hall,
                                 self.lineup_is_active[i],
                                 self.axes.designs[i], self.axes.envs[i])

    def results(self) -> List[FleetResult]:
        return [self.result(i) for i in range(len(self))]


@functools.partial(jax.jit,
                   static_argnames=("harvest", "mature_months", "with_pods"))
def _sweep_jit(jt, ft, idx, valid, policy, seed, h_cap, n_real, harvest,
               mature_months, with_pods):
    fn = functools.partial(simulate_lifecycle, harvest=harvest,
                           mature_months=mature_months, with_pods=with_pods)
    return jax.vmap(fn)(jt, ft, idx, valid, policy, seed, h_cap, n_real)


def sweep(axes: SweepAxes, harvest: bool = True, mature_months: int = 12,
          n_halls_max: int = 0,
          traces: Sequence[Trace] | None = None) -> SweepResult:
    """Evaluate every configuration in `axes` in one compiled call.

    All envelopes must share the same buildout horizon (the scan length).
    Returns a `SweepResult`; `result(i)` recovers the `FleetResult` a
    sequential `run_fleet(axes.config(i))` would produce (identical up to
    float-padding noise for score-based policies).
    """
    B = len(axes)
    if B == 0:
        raise ValueError("empty sweep")
    horizons = {(e.start_year, e.end_year) for e in axes.envs}
    if len(horizons) != 1:
        raise ValueError(f"envelopes span different horizons: {horizons}")
    months = (axes.envs[0].end_year - axes.envs[0].start_year + 1) * 12

    if traces is None:
        traces = [generate_fleet_trace(e, s)
                  for e, s in zip(axes.envs, axes.seeds)]
    if len(traces) != B:
        raise ValueError("need one trace per configuration")

    # ---- pad to common static shapes, bucketed so that sweeps over new
    # seeds/scenarios reuse the compiled executable (jit-cache hit) ----
    def bucket(n, q):
        return int(np.ceil(max(n, 1) / q) * q)

    h_caps = [n_halls_max or _auto_halls(d, e)
              for d, e in zip(axes.designs, axes.envs)]
    H_max = bucket(max(h_caps), 4)
    R_pad = max(d.n_rows for d in axes.designs)
    X_pad = max(d.n_lineups for d in axes.designs)
    topos = [build_topology(d, H_max, rows_per_hall=R_pad,
                            lineups_per_hall=X_pad) for d in axes.designs]
    jt = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[pl.jax_topology(t) for t in topos])

    E_max = bucket(max(len(t) for t in traces), 64)
    ft = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[FleetTrace.from_trace(t, pad_to=E_max,
                                              pad_month=months)
                        for t in traces])
    e_max = bucket(max(_month_e_max(t, months) for t in traces), 4)
    slices = [_month_slices(t, months, e_max=e_max, modulo=E_max)
              for t in traces]
    idx = jnp.asarray(np.stack([s[0] for s in slices]))
    valid = jnp.asarray(np.stack([s[1] for s in slices]))

    out = _sweep_jit(
        jt, ft, idx, valid,
        jnp.asarray(axes.policies, jnp.int32),
        jnp.asarray(axes.seeds, jnp.int32),
        jnp.asarray(h_caps, jnp.int32),
        jnp.asarray([len(t) for t in traces], jnp.int32),
        harvest=harvest, mature_months=mature_months,
        with_pods=any(bool(np.asarray(t.is_pod).any()) for t in traces))

    n_built = np.asarray(out.n_halls_built).astype(int)
    deployed_mw = np.asarray(out.final_deployed_kw) / 1e3
    initial = np.array([cost.initial_dollars_per_mw(d)
                        for d in axes.designs])
    effective = np.array([
        cost.effective_dollars_per_mw(d, int(n), float(mw))
        for d, n, mw in zip(axes.designs, n_built, deployed_mw)])
    capex = np.array([int(n) * cost.hall_capex(d)
                      for d, n in zip(axes.designs, n_built)])
    return SweepResult(
        axes=axes,
        months=np.arange(months),
        halls_active=np.asarray(out.halls_active),
        deployed_mw=np.asarray(out.deployed_kw) / 1e3,
        p50_stranding=np.asarray(out.p50_stranding),
        p90_stranding=np.asarray(out.p90_stranding),
        final_hall_stranding=np.asarray(out.final_hall_stranding),
        final_lineup_stranding=np.asarray(out.final_lineup_stranding),
        lineup_is_active=np.stack([np.asarray(t.lineup_is_active)
                                   for t in topos]),
        lineups_per_hall=X_pad,
        n_halls_built=n_built,
        final_deployed_mw=deployed_mw,
        placed_fraction=np.asarray(out.placed_fraction),
        initial_dpm=initial,
        effective_dpm=effective,
        total_capex=capex,
    )
