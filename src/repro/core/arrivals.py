"""Arrival envelopes and deployment-trace generation (paper §5.1–5.2).

Stage (1): class-level arrival envelopes — annual power targets per hardware
class (accelerators / general compute / storage) spread into monthly budgets
with seasonality weights.  Stage (2): per-SKU rack power via empirical SKU
clusters (Eq. 3).  Stage (3): lifecycle metadata (availability tier,
lifetime, harvest fraction).

Trace generation is host-side numpy (it parameterizes the simulations);
the placement simulators consume the resulting arrays on device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from . import projections as proj
from .resources import CLASS_COMPUTE, CLASS_GPU, CLASS_STORAGE, TIER_HA, TIER_LA

# SKU clusters (α_j, p_j) — stylized from the paper's Fig. 11 empirical
# clusters of Azure general-compute / storage rack-power distributions.
COMPUTE_SKUS = ((0.45, 0.25), (0.65, 0.35), (0.85, 0.25), (1.00, 0.15))
STORAGE_SKUS = ((0.60, 0.30), (0.80, 0.50), (1.00, 0.20))

# Lifetimes (paper §5.2): N(7,1) yrs compute/storage, N(5,0.5) yrs GPU.
LIFETIME = {CLASS_GPU: (5.0, 0.5), CLASS_COMPUTE: (7.0, 1.0),
            CLASS_STORAGE: (7.0, 1.0)}
# Harvest ceilings after 1 year (paper §5.2).
HARVEST_FRAC = {CLASS_GPU: 0.10, CLASS_COMPUTE: 0.15, CLASS_STORAGE: 0.15}

# Quarterly seasonality (stylized after Azure procurement cycles, §5.1).
SEASONALITY = np.array([0.8, 0.95, 1.05, 1.2])
SEASONALITY = np.repeat(SEASONALITY / SEASONALITY.sum(), 3) / 3.0  # monthly


@dataclass
class Trace:
    """Flat arrays, one entry per deployment event (cluster or pod)."""
    month: np.ndarray        # int32, months since start
    class_id: np.ndarray     # int32
    rack_kw: np.ndarray      # float32
    n_racks: np.ndarray      # int32
    is_gpu: np.ndarray       # bool
    is_pod: np.ndarray       # bool
    tier: np.ndarray         # int32
    lifetime_m: np.ndarray   # int32 months
    harvest_frac: np.ndarray  # float32

    def __len__(self):
        return len(self.month)

    @property
    def total_kw(self):
        return float(np.sum(self.rack_kw * self.n_racks))

    @staticmethod
    def concat(traces):
        return Trace(**{f: np.concatenate([getattr(t, f) for t in traces])
                        for f in Trace.__dataclass_fields__})

    def sorted_by_month(self):
        o = np.argsort(self.month, kind="stable")
        return Trace(**{f: getattr(self, f)[o]
                        for f in Trace.__dataclass_fields__})


@dataclass
class EnvelopeSpec:
    """Demand envelope (paper Table 1) plus beyond-the-paper scenario knobs.

    The paper baseline is 10 GW *cumulative* demand over the buildout
    horizon — 6.0 GW accelerators / 2.8 GW general compute / 1.2 GW
    storage — scaled uniformly by `demand_scale` (all `*_gw` fields are
    gigawatts; everything downstream of `annual_targets_kw` is kilowatts).
    Class ids are `resources.CLASS_GPU / CLASS_COMPUTE / CLASS_STORAGE`.

    The scenario-generator fields (see `repro.core.scenarios` and
    docs/scenarios.md) perturb the baseline; at their defaults
    (`shock_multiplier=1.0`, `cohort_window_m=0`, `refresh_cycle_m=0`,
    `mix_end=None`) the generated trace is bit-for-bit the paper grid's,
    so sweeps mixing baseline and scenario envelopes stay comparable.

    Paper-grid fields:
        start_year / end_year: buildout horizon (inclusive); the
            simulated month count is `(end_year - start_year + 1) * 12`.
        demand_scale: uniform multiplier on cumulative demand
            (1.0 ⇒ 10 GW; benchmarks default to a 0.04 ⇒ 400 MW miniature).
        gpu_gw / compute_gw / storage_gw: per-class cumulative demand [GW].
        growth: per-class annual demand growth factors (class id → rate).
        gpu_scenario / nongpu_scenario: rack-power TDP trajectory names
            (`projections.LOW/MED/HIGH`).
        pod_racks: GPU placement quantum in racks (1 = rack-scale, 3–7 =
            multi-rack pods).
        pod_scale_arch: use Kyber pod-scale racks from 2027 onward.
        quantum_racks: same-SKU racks per non-GPU cluster (§6.4).
        la_fraction: probability an arrival is low-availability tier
            (may consume failover headroom, §4.1).

    Scenario fields:
        shock_month: month index of a demand shock; -1 = no shock.
        shock_multiplier: monthly-budget multiplier after the shock
            (>1 surge, <1 bust; exactly 1.0 reproduces the baseline).
        shock_ramp_months: 0 = step at `shock_month`; >0 = linear ramp
            reaching `shock_multiplier` over that many months.
        cohort_window_m: >0 = correlated-lifetime cohorts: all same-class
            deployments arriving within one window share a decommission
            epoch instead of drawing independent lifetimes.
        refresh_cycle_m: >0 = decommission-wave refresh cycles:
            end-of-life months snap up to the next multiple of the cycle
            (hardware-generation turnover pulses).
        mix_end: optional (gpu, compute, storage) power-share tuple the
            per-year class split linearly interpolates toward by
            `end_year` (normalized; total annual demand is preserved).
    """
    start_year: int = 2026
    end_year: int = 2034
    demand_scale: float = 1.0          # 1.0 ⇒ 10 GW cumulative
    gpu_gw: float = 6.0
    compute_gw: float = 2.8
    storage_gw: float = 1.2
    growth: Dict[int, float] = field(default_factory=lambda: {
        CLASS_GPU: 1.35, CLASS_COMPUTE: 1.15, CLASS_STORAGE: 1.10})
    gpu_scenario: str = proj.MED
    nongpu_scenario: str = proj.MED
    pod_racks: int = 1                  # 1 = rack-scale GPU; 3–7 = pods
    pod_scale_arch: bool = False        # use Kyber pods from 2027
    quantum_racks: int = 10             # same-SKU racks per cluster (§6.4)
    la_fraction: float = 0.0            # share of LA-tier arrivals
    # --- scenario-generator knobs (repro.core.scenarios) ---
    shock_month: int = -1               # -1 = no demand shock
    shock_multiplier: float = 1.0       # budget multiplier after the shock
    shock_ramp_months: int = 0          # 0 = step; >0 = linear ramp-in
    cohort_window_m: int = 0            # 0 = independent lifetimes
    refresh_cycle_m: int = 0            # 0 = no refresh waves
    mix_end: Optional[Tuple[float, float, float]] = None

    @property
    def n_months(self) -> int:
        """Simulated month count of the buildout horizon."""
        return (self.end_year - self.start_year + 1) * 12

    def validate(self) -> "EnvelopeSpec":
        """Raise `SweepValidationError` on an unsatisfiable envelope."""
        from .hierarchy import SweepValidationError, _require
        e = self
        _require(e.end_year >= e.start_year, "end_year",
                 f"non-monotone buildout horizon: end_year {e.end_year} "
                 f"precedes start_year {e.start_year}")
        _require(e.demand_scale > 0, "demand_scale",
                 f"non-positive demand_scale {e.demand_scale}")
        _require(e.gpu_gw >= 0 and e.compute_gw >= 0 and e.storage_gw >= 0,
                 "gpu_gw", f"negative per-class demand (gpu_gw={e.gpu_gw}, "
                 f"compute_gw={e.compute_gw}, storage_gw={e.storage_gw})")
        _require(e.gpu_gw + e.compute_gw + e.storage_gw > 0, "gpu_gw",
                 "zero total demand; nothing would ever arrive")
        for cid in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE):
            _require(cid in e.growth, "growth",
                     f"growth is missing class id {cid}")
            _require(e.growth[cid] > 0, "growth",
                     f"non-positive growth factor {e.growth[cid]} for "
                     f"class id {cid}")
        for fld, sc in (("gpu_scenario", e.gpu_scenario),
                        ("nongpu_scenario", e.nongpu_scenario)):
            _require(sc in proj.SCENARIOS, fld,
                     f"unknown scenario {sc!r}; have {list(proj.SCENARIOS)}")
        from .placement import MAX_POD_RACKS
        _require(1 <= e.pod_racks <= MAX_POD_RACKS, "pod_racks",
                 f"pod_racks {e.pod_racks} outside [1, MAX_POD_RACKS="
                 f"{MAX_POD_RACKS}]; the pod window would exceed the "
                 f"placement scan length")
        _require(e.quantum_racks >= 1, "quantum_racks",
                 f"non-positive quantum_racks {e.quantum_racks}")
        _require(0.0 <= e.la_fraction <= 1.0, "la_fraction",
                 f"la_fraction {e.la_fraction} outside [0, 1]")
        _require(e.shock_month < e.n_months, "shock_month",
                 f"shock_month {e.shock_month} is past the horizon "
                 f"({e.n_months} months)")
        _require(e.shock_multiplier >= 0, "shock_multiplier",
                 f"negative shock_multiplier {e.shock_multiplier}")
        _require(e.shock_ramp_months >= 0, "shock_ramp_months",
                 f"negative shock_ramp_months {e.shock_ramp_months}")
        _require(e.cohort_window_m >= 0, "cohort_window_m",
                 f"negative cohort_window_m {e.cohort_window_m}")
        _require(e.refresh_cycle_m >= 0, "refresh_cycle_m",
                 f"negative refresh_cycle_m {e.refresh_cycle_m}")
        if e.mix_end is not None:
            _require(len(e.mix_end) == 3, "mix_end",
                     f"mix_end needs (gpu, compute, storage) shares, got "
                     f"{len(e.mix_end)} entries")
            _require(all(s >= 0 for s in e.mix_end) and sum(e.mix_end) > 0,
                     "mix_end", f"mix_end shares {e.mix_end} must be "
                     f"non-negative and sum positive")
        return e

    def annual_targets_kw(self, class_id: int) -> np.ndarray:
        """Per-year arrival power targets [kW] for one hardware class.

        Baseline: the class's cumulative demand spread over the horizon
        with its compound `growth` weighting.  With `mix_end` set, the
        *combined* annual total is preserved and the per-year class split
        interpolates linearly from the baseline split at `start_year` to
        the normalized `mix_end` shares at `end_year`.
        """
        years = np.arange(self.start_year, self.end_year + 1)

        def base(cid):
            total_gw = {CLASS_GPU: self.gpu_gw,
                        CLASS_COMPUTE: self.compute_gw,
                        CLASS_STORAGE: self.storage_gw}[cid]
            w = self.growth[cid] ** np.arange(len(years))
            return total_gw * 1e6 * self.demand_scale * w / w.sum()

        if self.mix_end is None:
            return base(class_id)
        per_class = {c: base(c)
                     for c in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE)}
        tot = sum(per_class.values())                     # [Y] combined
        end = np.asarray(self.mix_end, float)
        end = end / end.sum()
        # 0 at start_year, 1 at end_year; a one-year horizon IS end_year
        f = np.linspace(0.0, 1.0, len(years)) if len(years) > 1 \
            else np.ones(1)
        share = ((1.0 - f) * per_class[class_id] / np.maximum(tot, 1e-12)
                 + f * end[class_id])
        return tot * share

    def monthly_multipliers(self) -> np.ndarray:
        """[n_months] demand-shock multiplier on the monthly budgets.

        All-ones without a shock (`shock_month < 0`); a step to
        `shock_multiplier` at `shock_month`, or a linear ramp over
        `shock_ramp_months` months reaching it.  A multiplier of exactly
        1.0 leaves every budget bit-identical to the baseline.
        """
        t = np.arange(self.n_months, dtype=float)
        if self.shock_month < 0:
            return np.ones_like(t)
        if self.shock_ramp_months > 0:
            frac = np.clip((t - self.shock_month) / self.shock_ramp_months,
                           0.0, 1.0)
        else:
            frac = (t >= self.shock_month).astype(float)
        return 1.0 + frac * (self.shock_multiplier - 1.0)

    def demand_multiplier(self) -> float:
        """Budget-weighted mean of `monthly_multipliers` — the factor by
        which a demand shock scales *cumulative* demand (1.0 without a
        shock).  Used by hall auto-sizing (`fleet._auto_halls`) so surge
        scenarios still get enough hall headroom."""
        if self.shock_month < 0:
            return 1.0
        mult = self.monthly_multipliers()
        num = den = 0.0
        for cid in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE):
            w = np.outer(self.annual_targets_kw(cid), SEASONALITY).ravel()
            num += float(w @ mult)
            den += float(w.sum())
        return num / max(den, 1e-12)


def _rack_kw_for(env: EnvelopeSpec, class_id: int, year: int,
                 rng: np.random.Generator) -> float:
    if class_id == CLASS_GPU:
        return proj.gpu_rack_kw(year, env.gpu_scenario,
                                pod_scale=env.pod_scale_arch or env.pod_racks > 1)
    if class_id == CLASS_COMPUTE:
        pmax, skus = proj.compute_rack_kw(year, env.nongpu_scenario), COMPUTE_SKUS
    else:
        pmax, skus = proj.storage_rack_kw(year, env.nongpu_scenario), STORAGE_SKUS
    alphas = np.array([a for a, _ in skus])
    probs = np.array([p for _, p in skus])
    return float(pmax * rng.choice(alphas, p=probs))     # Eq. 3


def _correlate_cohorts(t: Trace, window_m: int, seed: int) -> Trace:
    """Correlated-lifetime cohorts (`EnvelopeSpec.cohort_window_m`).

    Replaces the per-deployment N(μ,σ) lifetimes with a shared
    per-(class, window) decommission epoch: one lifetime is drawn per
    cohort (seeded by `(seed, class, cohort)`, so traces stay
    reproducible) relative to the window start, and every member's
    `lifetime_m` is set so `month + lifetime_m` lands on that epoch.
    The epoch is floored at the window *end*, so even windows wider
    than the lifetime draw keep the whole cohort on one shared epoch
    (late-window arrivals just live at least one month).
    """
    cohort = t.month // window_m
    life = np.asarray(t.lifetime_m).copy()
    for cid in np.unique(t.class_id):
        mu, sd = LIFETIME[int(cid)]
        in_class = t.class_id == cid
        for c in np.unique(cohort[in_class]):
            crng = np.random.default_rng([seed, int(cid), int(c), 0xC0C0])
            epoch = int(c) * window_m + max(
                window_m, 12, int(round(crng.normal(mu, sd) * 12)))
            sel = in_class & (cohort == c)
            life[sel] = np.maximum(1, epoch - t.month[sel])
    t.lifetime_m = life.astype(np.int32)
    return t


def _snap_refresh_waves(t: Trace, cycle_m: int) -> Trace:
    """Decommission-wave refresh cycles (`EnvelopeSpec.refresh_cycle_m`):
    every end-of-life month snaps *up* to the next multiple of the cycle,
    turning the smooth decommission stream into generation-turnover
    pulses (deployment months are untouched)."""
    decom = t.month + t.lifetime_m
    wave = -(-decom // cycle_m) * cycle_m          # ceil to next wave epoch
    t.lifetime_m = np.maximum(1, wave - t.month).astype(np.int32)
    return t


def generate_fleet_trace(env: EnvelopeSpec, seed: int = 0) -> Trace:
    """Multi-year deployment trace over the buildout horizon (§5.1).

    Spreads each class's annual targets (`env.annual_targets_kw`, kW)
    into monthly budgets with procurement seasonality and the envelope's
    demand-shock multipliers, then emits whole deployment events (GPU
    pods of `pod_racks`, non-GPU clusters of `quantum_racks`) until each
    budget is spent, carrying over-spend debt into the next month.
    Per-event rack power comes from the TDP projections (GPU) or the
    empirical SKU clusters (Eq. 3); lifetimes are N(μ,σ) draws
    (`LIFETIME`, months) unless the envelope's cohort/refresh knobs
    post-process them (see `_correlate_cohorts` / `_snap_refresh_waves`).

    All powers are kilowatts (`Trace.rack_kw` is per-rack kW; an event's
    power is `rack_kw * n_racks`).  `seed` fully determines the trace:
    the same `(env, seed)` pair is bit-for-bit reproducible, and
    scenario knobs at their neutral defaults (multiplier 1.0, window 0,
    cycle 0, `mix_end=None`) leave the draw sequence — hence the trace —
    identical to the paper baseline.  Returns the events sorted by
    arrival month (stable).
    """
    rng = np.random.default_rng(seed)
    years = np.arange(env.start_year, env.end_year + 1)
    mult = env.monthly_multipliers()
    recs = {f: [] for f in Trace.__dataclass_fields__}

    def emit(month, class_id, rack_kw, n_racks, is_pod, year):
        mu, sd = LIFETIME[class_id]
        life = max(12, int(round(rng.normal(mu, sd) * 12)))
        tier = TIER_LA if rng.random() < env.la_fraction else TIER_HA
        recs["month"].append(month)
        recs["class_id"].append(class_id)
        recs["rack_kw"].append(rack_kw)
        recs["n_racks"].append(n_racks)
        recs["is_gpu"].append(class_id == CLASS_GPU)
        recs["is_pod"].append(is_pod)
        recs["tier"].append(tier)
        recs["lifetime_m"].append(life)
        recs["harvest_frac"].append(HARVEST_FRAC[class_id])

    for class_id in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE):
        targets = env.annual_targets_kw(class_id)
        carry = 0.0          # over-spend debt carried into the next month
        for yi, year in enumerate(years):
            for mo in range(12):
                month = yi * 12 + mo
                budget = targets[yi] * SEASONALITY[mo] * mult[month] + carry
                spent = 0.0
                while spent < budget:
                    kw = _rack_kw_for(env, class_id, year, rng)
                    if class_id == CLASS_GPU:
                        n = env.pod_racks if env.pod_racks > 1 else 1
                        is_pod = env.pod_racks > 1
                    else:
                        n = env.quantum_racks
                        is_pod = False
                    emit(month, class_id, kw, n, is_pod, year)
                    spent += kw * n
                carry = budget - spent

    t = Trace(**{f: np.asarray(v) for f, v in recs.items()})
    t.month = t.month.astype(np.int32)
    t.class_id = t.class_id.astype(np.int32)
    t.rack_kw = t.rack_kw.astype(np.float32)
    t.n_racks = t.n_racks.astype(np.int32)
    t.tier = t.tier.astype(np.int32)
    t.lifetime_m = t.lifetime_m.astype(np.int32)
    t.harvest_frac = t.harvest_frac.astype(np.float32)
    if env.cohort_window_m > 0:
        t = _correlate_cohorts(t, env.cohort_window_m, seed)
    if env.refresh_cycle_m > 0:
        t = _snap_refresh_waves(t, env.refresh_cycle_m)
    return t.sorted_by_month()


@dataclass
class TraceBatch:
    """A batch of steady-state traces: every column is `[T, E]` (trial-major).

    Produced by `sample_mixed_traces` in one vectorized numpy RNG pass —
    the batched analogue of calling `sample_mixed_trace` once per trial.
    `trial(i)` recovers trial `i` as a plain 1-D `Trace`.
    """
    month: np.ndarray        # int32 [T, E]
    class_id: np.ndarray     # int32 [T, E]
    rack_kw: np.ndarray      # float32 [T, E]
    n_racks: np.ndarray      # int32 [T, E]
    is_gpu: np.ndarray       # bool [T, E]
    is_pod: np.ndarray       # bool [T, E]
    tier: np.ndarray         # int32 [T, E]
    lifetime_m: np.ndarray   # int32 [T, E]
    harvest_frac: np.ndarray  # float32 [T, E]

    def __len__(self):
        return self.month.shape[0]

    def trial(self, i: int) -> Trace:
        return Trace(**{f: getattr(self, f)[i]
                        for f in Trace.__dataclass_fields__})

    @property
    def n_pods(self) -> np.ndarray:
        """Per-trial pod-event count [T].  `sample_mixed_traces` emits
        pods first within every trial, so trial `t`'s pod events are
        exactly indices ``[0, n_pods[t])`` — the split-trace contract."""
        return self.is_pod.sum(axis=1).astype(np.int32)

    @property
    def max_pod_racks(self) -> int:
        """The batch's true largest pod size in racks (1 if pod-free) —
        the static rack-scan length the split-pods path needs."""
        pods = np.asarray(self.is_pod)
        return int(np.asarray(self.n_racks)[pods].max()) if pods.any() else 1


def sample_mixed_traces(n_trials: int, n_events: int, year: int = 2028,
                        scenario: str = proj.MED, seed: int = 0,
                        gpu_power_share: float = 0.6,
                        pod_racks: int = 1, quantum_racks: int = 10,
                        la_fraction: float = 0.0,
                        sku_kw_override: float | None = None,
                        single_sku_gpu: bool = False,
                        phase: int = 0) -> TraceBatch:
    """Batched `sample_mixed_trace`: `n_trials` steady-state traces in ONE
    vectorized numpy RNG pass (no per-trial / per-event Python loop).

    The single-hall Monte Carlo engine (`repro.core.mc_sweep.mc_sweep`)
    consumes this directly; host-side trace synthesis used to dominate its
    wall time at small `n_events`.  Semantics match `sample_mixed_trace`
    (class mix calibrated from mean event power, SKU clusters per Eq. 3,
    N(μ,σ) lifetimes, LA tiers with probability `la_fraction`) with three
    deliberate differences:

    * the RNG is one `np.random.default_rng([seed, trial-batch salt])`
      stream drawing `[T, E]` grids, so a batch is bit-for-bit
      reproducible for equal arguments but individual trials are NOT
      bitwise-identical to per-trial `sample_mixed_trace` calls (the
      distributions are identical — equivalence is statistical);
    * the Fig. 6 single-SKU mode is a *generator argument*
      (`single_sku_gpu` + `sku_kw_override`) instead of post-hoc in-place
      mutation: `single_sku_gpu=True` emits only GPU-class events, and
      `sku_kw_override` replaces every GPU rack power;
    * with `pod_racks > 1` every trial's events are reordered **pods
      first** (stable, so relative order within pods and within clusters
      is preserved) — the same per-window contract the fleet trace keeps
      per month, which lets the split-pods scan run a pod window then a
      cluster window without reordering anything at placement time.
      `TraceBatch.n_pods` / `max_pod_racks` expose the window geometry.

    `phase` salts an independent stream per (seed, phase) pair — the MC
    engine draws fill traces at phase 0 and refill traces at phase 1, so
    a configuration seeded `s` never shares a stream with configuration
    `s+1` (phase 0 keeps the historical `[seed, salt]` stream).
    """
    salt = ([int(seed), 0x6D63] if phase == 0
            else [int(seed), int(phase), 0x6D63])      # 'mc' trial salt
    rng = np.random.default_rng(salt)
    T, E = int(n_trials), int(n_events)
    gpu_n = pod_racks if pod_racks > 1 else 1
    gpu_kw = proj.gpu_rack_kw(year, scenario, pod_scale=pod_racks > 1)

    if single_sku_gpu:
        cid = np.full((T, E), CLASS_GPU, np.int32)
    else:
        shares = {CLASS_GPU: gpu_power_share,
                  CLASS_COMPUTE: (1 - gpu_power_share) * 0.7,
                  CLASS_STORAGE: (1 - gpu_power_share) * 0.3}
        # power shares → event probabilities via mean event power, with the
        # same 64-draw calibration `sample_mixed_trace` uses (vectorized)
        mean_event_kw = {CLASS_GPU: gpu_kw * gpu_n}
        for cls, pmax_fn, skus in (
                (CLASS_COMPUTE, proj.compute_rack_kw, COMPUTE_SKUS),
                (CLASS_STORAGE, proj.storage_rack_kw, STORAGE_SKUS)):
            alphas = np.array([a for a, _ in skus])
            probs = np.array([p for _, p in skus])
            draws = pmax_fn(year, scenario) * rng.choice(alphas, size=64,
                                                         p=probs)
            mean_event_kw[cls] = draws.mean() * quantum_racks
        p = np.array([shares[c] / mean_event_kw[c]
                      for c in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE)])
        cid = rng.choice(np.array([CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE],
                                  np.int32), size=(T, E),
                         p=p / p.sum()).astype(np.int32)
    is_gpu = cid == CLASS_GPU

    # per-SKU rack power (Eq. 3), one choice grid per non-GPU class
    def sku_kw(pmax, skus):
        alphas = np.array([a for a, _ in skus])
        probs = np.array([p for _, p in skus])
        return pmax * rng.choice(alphas, size=(T, E), p=probs)

    rack_kw = np.where(
        is_gpu, gpu_kw,
        np.where(cid == CLASS_COMPUTE,
                 sku_kw(proj.compute_rack_kw(year, scenario), COMPUTE_SKUS),
                 sku_kw(proj.storage_rack_kw(year, scenario), STORAGE_SKUS)))
    if sku_kw_override is not None:
        rack_kw = np.where(is_gpu, float(sku_kw_override), rack_kw)

    tier = np.where(rng.random((T, E)) < la_fraction, TIER_LA, TIER_HA)
    mu = np.array([LIFETIME[c][0] for c in range(3)])[cid]
    sd = np.array([LIFETIME[c][1] for c in range(3)])[cid]
    lifetime_m = np.maximum(12, np.round(rng.normal(mu, sd) * 12.0))

    if pod_racks > 1:
        # pods-first per trial (stable — in-group order preserved): the
        # split-trace contract; a pure reorder, so per-event marginals
        # and the realized power mix are untouched
        order = np.argsort(~is_gpu, axis=1, kind="stable")
        take = lambda a: np.take_along_axis(a, order, axis=1)
        cid, rack_kw, tier, lifetime_m = map(
            take, (cid, rack_kw, tier, lifetime_m))
        is_gpu = cid == CLASS_GPU

    return TraceBatch(
        month=np.zeros((T, E), np.int32),
        class_id=cid,
        rack_kw=rack_kw.astype(np.float32),
        n_racks=np.where(is_gpu, gpu_n, quantum_racks).astype(np.int32),
        is_gpu=is_gpu,
        is_pod=is_gpu & (pod_racks > 1),
        tier=tier.astype(np.int32),
        lifetime_m=lifetime_m.astype(np.int32),
        harvest_frac=np.array([HARVEST_FRAC[c]
                               for c in range(3)])[cid].astype(np.float32),
    )


def sample_mixed_trace(n_events: int, year: int = 2028,
                       scenario: str = proj.MED, seed: int = 0,
                       gpu_power_share: float = 0.6,
                       pod_racks: int = 1, quantum_racks: int = 10,
                       la_fraction: float = 0.0) -> Trace:
    """Steady-state mixed-SKU stream for single-hall Monte Carlo (§4.4).

    Unlike `generate_fleet_trace` there is no buildout calendar: all
    `n_events` arrive at month 0 (the saturation simulator places them
    until the hall fills).  Event *class* probabilities are derived from
    the target power shares — GPU gets `gpu_power_share` of added power,
    the remainder splits 0.7/0.3 between general compute and storage —
    by dividing each share by the class's empirical mean event power
    (64 calibration draws per class), so the realized power mix matches
    the requested split.  `rack_kw` is per-rack kilowatts; an event's
    power is `rack_kw * n_racks` with `n_racks = pod_racks` for GPU pods
    (1 if rack-scale) and `quantum_racks` otherwise.  `seed` drives one
    `np.random.default_rng` stream through calibration and sampling, so
    equal `(n_events, year, scenario, seed, …)` calls are bit-for-bit
    reproducible; class ids are `resources.CLASS_*`, tiers
    `resources.TIER_HA/TIER_LA` (LA with probability `la_fraction`).
    """
    rng = np.random.default_rng(seed)
    env = EnvelopeSpec(gpu_scenario=scenario, nongpu_scenario=scenario,
                       pod_racks=pod_racks, quantum_racks=quantum_racks,
                       la_fraction=la_fraction)
    shares = {CLASS_GPU: gpu_power_share,
              CLASS_COMPUTE: (1 - gpu_power_share) * 0.7,
              CLASS_STORAGE: (1 - gpu_power_share) * 0.3}
    # convert power shares → event probabilities via mean event power
    mean_event_kw = {}
    for cid in shares:
        kws = [_rack_kw_for(env, cid, year, rng) for _ in range(64)]
        n = pod_racks if (cid == CLASS_GPU and pod_racks > 1) else (
            1 if cid == CLASS_GPU else quantum_racks)
        mean_event_kw[cid] = np.mean(kws) * n
    p = np.array([shares[c] / mean_event_kw[c]
                  for c in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE)])
    p = p / p.sum()

    recs = {f: [] for f in Trace.__dataclass_fields__}
    for i in range(n_events):
        cid = int(rng.choice([CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE], p=p))
        kw = _rack_kw_for(env, cid, year, rng)
        if cid == CLASS_GPU:
            n, is_pod = (pod_racks, pod_racks > 1) if pod_racks > 1 else (1, False)
        else:
            n, is_pod = quantum_racks, False
        mu, sd = LIFETIME[cid]
        recs["month"].append(0)
        recs["class_id"].append(cid)
        recs["rack_kw"].append(kw)
        recs["n_racks"].append(n)
        recs["is_gpu"].append(cid == CLASS_GPU)
        recs["is_pod"].append(is_pod)
        recs["tier"].append(TIER_LA if rng.random() < la_fraction else TIER_HA)
        recs["lifetime_m"].append(max(12, int(round(rng.normal(mu, sd) * 12))))
        recs["harvest_frac"].append(HARVEST_FRAC[cid])

    t = Trace(**{f: np.asarray(v) for f, v in recs.items()})
    t.month = t.month.astype(np.int32)
    t.class_id = t.class_id.astype(np.int32)
    t.rack_kw = t.rack_kw.astype(np.float32)
    t.n_racks = t.n_racks.astype(np.int32)
    t.tier = t.tier.astype(np.int32)
    t.lifetime_m = t.lifetime_m.astype(np.int32)
    t.harvest_frac = t.harvest_frac.astype(np.float32)
    return t
