"""Arrival envelopes and deployment-trace generation (paper §5.1–5.2).

Stage (1): class-level arrival envelopes — annual power targets per hardware
class (accelerators / general compute / storage) spread into monthly budgets
with seasonality weights.  Stage (2): per-SKU rack power via empirical SKU
clusters (Eq. 3).  Stage (3): lifecycle metadata (availability tier,
lifetime, harvest fraction).

Trace generation is host-side numpy (it parameterizes the simulations);
the placement simulators consume the resulting arrays on device.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from . import projections as proj
from .resources import CLASS_COMPUTE, CLASS_GPU, CLASS_STORAGE, TIER_HA, TIER_LA

# SKU clusters (α_j, p_j) — stylized from the paper's Fig. 11 empirical
# clusters of Azure general-compute / storage rack-power distributions.
COMPUTE_SKUS = ((0.45, 0.25), (0.65, 0.35), (0.85, 0.25), (1.00, 0.15))
STORAGE_SKUS = ((0.60, 0.30), (0.80, 0.50), (1.00, 0.20))

# Lifetimes (paper §5.2): N(7,1) yrs compute/storage, N(5,0.5) yrs GPU.
LIFETIME = {CLASS_GPU: (5.0, 0.5), CLASS_COMPUTE: (7.0, 1.0),
            CLASS_STORAGE: (7.0, 1.0)}
# Harvest ceilings after 1 year (paper §5.2).
HARVEST_FRAC = {CLASS_GPU: 0.10, CLASS_COMPUTE: 0.15, CLASS_STORAGE: 0.15}

# Quarterly seasonality (stylized after Azure procurement cycles, §5.1).
SEASONALITY = np.array([0.8, 0.95, 1.05, 1.2])
SEASONALITY = np.repeat(SEASONALITY / SEASONALITY.sum(), 3) / 3.0  # monthly


@dataclass
class Trace:
    """Flat arrays, one entry per deployment event (cluster or pod)."""
    month: np.ndarray        # int32, months since start
    class_id: np.ndarray     # int32
    rack_kw: np.ndarray      # float32
    n_racks: np.ndarray      # int32
    is_gpu: np.ndarray       # bool
    is_pod: np.ndarray       # bool
    tier: np.ndarray         # int32
    lifetime_m: np.ndarray   # int32 months
    harvest_frac: np.ndarray  # float32

    def __len__(self):
        return len(self.month)

    @property
    def total_kw(self):
        return float(np.sum(self.rack_kw * self.n_racks))

    @staticmethod
    def concat(traces):
        return Trace(**{f: np.concatenate([getattr(t, f) for t in traces])
                        for f in Trace.__dataclass_fields__})

    def sorted_by_month(self):
        o = np.argsort(self.month, kind="stable")
        return Trace(**{f: getattr(self, f)[o]
                        for f in Trace.__dataclass_fields__})


@dataclass
class EnvelopeSpec:
    """Demand envelope (paper Table 1: 10 GW cumulative by default —
    6.0 GPU / 2.8 compute / 1.2 storage — scalable via `demand_scale`)."""
    start_year: int = 2026
    end_year: int = 2034
    demand_scale: float = 1.0          # 1.0 ⇒ 10 GW cumulative
    gpu_gw: float = 6.0
    compute_gw: float = 2.8
    storage_gw: float = 1.2
    growth: Dict[int, float] = field(default_factory=lambda: {
        CLASS_GPU: 1.35, CLASS_COMPUTE: 1.15, CLASS_STORAGE: 1.10})
    gpu_scenario: str = proj.MED
    nongpu_scenario: str = proj.MED
    pod_racks: int = 1                  # 1 = rack-scale GPU; 3–7 = pods
    pod_scale_arch: bool = False        # use Kyber pods from 2027
    quantum_racks: int = 10             # same-SKU racks per cluster (§6.4)
    la_fraction: float = 0.0            # share of LA-tier arrivals

    def annual_targets_kw(self, class_id: int) -> np.ndarray:
        total_gw = {CLASS_GPU: self.gpu_gw, CLASS_COMPUTE: self.compute_gw,
                    CLASS_STORAGE: self.storage_gw}[class_id]
        total_kw = total_gw * 1e6 * self.demand_scale
        years = np.arange(self.start_year, self.end_year + 1)
        w = self.growth[class_id] ** np.arange(len(years))
        return total_kw * w / w.sum()


def _rack_kw_for(env: EnvelopeSpec, class_id: int, year: int,
                 rng: np.random.Generator) -> float:
    if class_id == CLASS_GPU:
        return proj.gpu_rack_kw(year, env.gpu_scenario,
                                pod_scale=env.pod_scale_arch or env.pod_racks > 1)
    if class_id == CLASS_COMPUTE:
        pmax, skus = proj.compute_rack_kw(year, env.nongpu_scenario), COMPUTE_SKUS
    else:
        pmax, skus = proj.storage_rack_kw(year, env.nongpu_scenario), STORAGE_SKUS
    alphas = np.array([a for a, _ in skus])
    probs = np.array([p for _, p in skus])
    return float(pmax * rng.choice(alphas, p=probs))     # Eq. 3


def generate_fleet_trace(env: EnvelopeSpec, seed: int = 0) -> Trace:
    """Multi-year deployment trace over the buildout horizon (§5.1)."""
    rng = np.random.default_rng(seed)
    years = np.arange(env.start_year, env.end_year + 1)
    recs = {f: [] for f in Trace.__dataclass_fields__}

    def emit(month, class_id, rack_kw, n_racks, is_pod, year):
        mu, sd = LIFETIME[class_id]
        life = max(12, int(round(rng.normal(mu, sd) * 12)))
        tier = TIER_LA if rng.random() < env.la_fraction else TIER_HA
        recs["month"].append(month)
        recs["class_id"].append(class_id)
        recs["rack_kw"].append(rack_kw)
        recs["n_racks"].append(n_racks)
        recs["is_gpu"].append(class_id == CLASS_GPU)
        recs["is_pod"].append(is_pod)
        recs["tier"].append(tier)
        recs["lifetime_m"].append(life)
        recs["harvest_frac"].append(HARVEST_FRAC[class_id])

    for class_id in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE):
        targets = env.annual_targets_kw(class_id)
        carry = 0.0          # over-spend debt carried into the next month
        for yi, year in enumerate(years):
            for mo in range(12):
                month = yi * 12 + mo
                budget = targets[yi] * SEASONALITY[mo] + carry
                spent = 0.0
                while spent < budget:
                    kw = _rack_kw_for(env, class_id, year, rng)
                    if class_id == CLASS_GPU:
                        n = env.pod_racks if env.pod_racks > 1 else 1
                        is_pod = env.pod_racks > 1
                    else:
                        n = env.quantum_racks
                        is_pod = False
                    emit(month, class_id, kw, n, is_pod, year)
                    spent += kw * n
                carry = budget - spent

    t = Trace(**{f: np.asarray(v) for f, v in recs.items()})
    t.month = t.month.astype(np.int32)
    t.class_id = t.class_id.astype(np.int32)
    t.rack_kw = t.rack_kw.astype(np.float32)
    t.n_racks = t.n_racks.astype(np.int32)
    t.tier = t.tier.astype(np.int32)
    t.lifetime_m = t.lifetime_m.astype(np.int32)
    t.harvest_frac = t.harvest_frac.astype(np.float32)
    return t.sorted_by_month()


def sample_mixed_trace(n_events: int, year: int = 2028,
                       scenario: str = proj.MED, seed: int = 0,
                       gpu_power_share: float = 0.6,
                       pod_racks: int = 1, quantum_racks: int = 10,
                       la_fraction: float = 0.0) -> Trace:
    """Steady-state mixed-SKU stream for single-hall Monte Carlo (§4.4).

    Event class probabilities are derived from the target *power* shares
    (GPU/compute/storage ≈ gpu_share/0.7·rest/0.3·rest of added power).
    """
    rng = np.random.default_rng(seed)
    env = EnvelopeSpec(gpu_scenario=scenario, nongpu_scenario=scenario,
                       pod_racks=pod_racks, quantum_racks=quantum_racks,
                       la_fraction=la_fraction)
    shares = {CLASS_GPU: gpu_power_share,
              CLASS_COMPUTE: (1 - gpu_power_share) * 0.7,
              CLASS_STORAGE: (1 - gpu_power_share) * 0.3}
    # convert power shares → event probabilities via mean event power
    mean_event_kw = {}
    for cid in shares:
        kws = [_rack_kw_for(env, cid, year, rng) for _ in range(64)]
        n = pod_racks if (cid == CLASS_GPU and pod_racks > 1) else (
            1 if cid == CLASS_GPU else quantum_racks)
        mean_event_kw[cid] = np.mean(kws) * n
    p = np.array([shares[c] / mean_event_kw[c]
                  for c in (CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE)])
    p = p / p.sum()

    recs = {f: [] for f in Trace.__dataclass_fields__}
    for i in range(n_events):
        cid = int(rng.choice([CLASS_GPU, CLASS_COMPUTE, CLASS_STORAGE], p=p))
        kw = _rack_kw_for(env, cid, year, rng)
        if cid == CLASS_GPU:
            n, is_pod = (pod_racks, pod_racks > 1) if pod_racks > 1 else (1, False)
        else:
            n, is_pod = quantum_racks, False
        mu, sd = LIFETIME[cid]
        recs["month"].append(0)
        recs["class_id"].append(cid)
        recs["rack_kw"].append(kw)
        recs["n_racks"].append(n)
        recs["is_gpu"].append(cid == CLASS_GPU)
        recs["is_pod"].append(is_pod)
        recs["tier"].append(TIER_LA if rng.random() < la_fraction else TIER_HA)
        recs["lifetime_m"].append(max(12, int(round(rng.normal(mu, sd) * 12))))
        recs["harvest_frac"].append(HARVEST_FRAC[cid])

    t = Trace(**{f: np.asarray(v) for f, v in recs.items()})
    t.month = t.month.astype(np.int32)
    t.class_id = t.class_id.astype(np.int32)
    t.rack_kw = t.rack_kw.astype(np.float32)
    t.n_racks = t.n_racks.astype(np.int32)
    t.tier = t.tier.astype(np.int32)
    t.lifetime_m = t.lifetime_m.astype(np.int32)
    t.harvest_frac = t.harvest_frac.astype(np.float32)
    return t
