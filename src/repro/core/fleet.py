"""Fleet-scale lifecycle simulator (paper §4.4, Fig. 8 pipeline).

Places a multi-year arrival trace across a growing fleet of identical
halls: opens a new hall when no feasible placement exists (instant
commissioning, §4.2), harvests racks one year after deployment, and
decommissions racks at end-of-life.  The monthly loop is host-side Python
(108 iterations); each month's decommission/harvest/placement work runs as
one jitted step over padded static shapes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import arrivals, cost, placement as pl
from .arrivals import EnvelopeSpec, Trace, generate_fleet_trace
from .hierarchy import DesignSpec, build_topology
from .placement import DEFAULT_POLICY, Deployment, MAX_POD_RACKS


@dataclass
class FleetConfig:
    design: DesignSpec
    env: EnvelopeSpec = field(default_factory=EnvelopeSpec)
    policy: int = DEFAULT_POLICY
    harvest: bool = True
    seed: int = 0
    n_halls_max: int = 0          # 0 → auto-size from demand
    mature_months: int = 12       # halls older than this enter tail stats


@dataclass
class FleetResult:
    months: np.ndarray            # [M]
    halls_active: np.ndarray      # [M]
    deployed_mw: np.ndarray       # [M]
    p50_stranding: np.ndarray     # [M] over mature halls
    p90_stranding: np.ndarray     # [M]
    final_hall_stranding: np.ndarray   # [H_active]
    final_lineup_stranding: np.ndarray  # [X_active] (active halls)
    n_halls_built: int
    final_deployed_mw: float
    placed_fraction: float
    design: DesignSpec = None
    env: EnvelopeSpec = None

    @property
    def initial_dpm(self):
        return cost.initial_dollars_per_mw(self.design)

    @property
    def effective_dpm(self):
        return cost.effective_dollars_per_mw(
            self.design, self.n_halls_built, self.final_deployed_mw)

    @property
    def total_capex(self):
        return self.n_halls_built * cost.hall_capex(self.design)


def _auto_halls(design: DesignSpec, env: EnvelopeSpec) -> int:
    total_mw = (env.gpu_gw + env.compute_gw + env.storage_gw) * 1e3 * env.demand_scale
    # decommissioning returns capacity; 45% slack covers stranding + churn
    return int(np.ceil(total_mw / (design.ha_capacity_kw / 1e3) * 1.45)) + 4


def run_fleet(cfg: FleetConfig, trace: Trace | None = None) -> FleetResult:
    design, env = cfg.design, cfg.env
    if trace is None:
        trace = generate_fleet_trace(env, cfg.seed)
    months = (env.end_year - env.start_year + 1) * 12
    H = cfg.n_halls_max or _auto_halls(design, env)
    topo = build_topology(design, H)
    jt = pl.jax_topology(topo)
    state = pl.init_state(topo)

    E = len(trace)
    # month slicing (trace sorted by month)
    starts = np.searchsorted(trace.month, np.arange(months))
    ends = np.searchsorted(trace.month, np.arange(months), side="right")
    e_max = max(1, int((ends - starts).max()))

    # device-side trace columns
    tr = {f: jnp.asarray(getattr(trace, f)) for f in
          ("rack_kw", "n_racks", "is_gpu", "is_pod", "tier",
           "harvest_frac", "lifetime_m", "month")}

    # registry (device): where each event's racks landed
    reg_rows = jnp.full((E, MAX_POD_RACKS), -1, jnp.int32)
    reg_counts = jnp.zeros((E, MAX_POD_RACKS), jnp.float32)
    placed = jnp.zeros((E,), bool)
    harvested = jnp.zeros((E,), bool)
    removed = jnp.zeros((E,), bool)

    row_hall = jnp.asarray(topo.row_hall)

    @functools.partial(jax.jit, static_argnames=())
    def step_month(state, reg_rows, reg_counts, placed, harvested, removed,
                   n_active, month, idx, valid, key):
        # ---- 1. decommission expired racks ----
        expire = placed & ~removed & (tr["month"] + tr["lifetime_m"] <= month)
        frac_dec = jnp.where(expire,
                             1.0 - jnp.where(harvested, tr["harvest_frac"], 0.0),
                             0.0)
        state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                tr["rack_kw"], tr["is_gpu"], tr["tier"],
                                frac_dec)
        removed = removed | expire

        # ---- 2. harvest one-year-old racks ----
        if cfg.harvest:
            h = placed & ~removed & ~harvested & (tr["month"] + 12 <= month)
            state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                    tr["rack_kw"], tr["is_gpu"], tr["tier"],
                                    jnp.where(h, tr["harvest_frac"], 0.0))
            harvested = harvested | h

        # ---- 3. place this month's arrivals ----
        def body(carry, i):
            st, n_act, rr, rc, plcd = carry
            e = idx[i]
            dep = Deployment(tr["rack_kw"][e], tr["n_racks"][e],
                             tr["is_gpu"][e], tr["tier"][e], tr["is_pod"][e])
            k = jax.random.fold_in(key, i)

            def attempt(n):
                active = row_hall < n
                return pl.place(jt, st, dep, cfg.policy, k, active)

            st1, ok1, rows1, counts1 = attempt(n_act)

            def retry():
                n2 = jnp.minimum(n_act + 1, H)
                st2, ok2, rows2, counts2 = attempt(n2)
                return st2, ok2, rows2, counts2, n2

            st_f, ok_f, rows_f, counts_f, n_f = jax.lax.cond(
                ok1, lambda: (st1, ok1, rows1, counts1, n_act), retry)

            live = valid[i]
            ok_f = ok_f & live
            st = pl._tree_where(ok_f, st_f, st)
            n_act = jnp.where(live, n_f, n_act)
            rr = rr.at[e].set(jnp.where(ok_f, rows_f, rr[e]))
            rc = rc.at[e].set(jnp.where(ok_f, counts_f, rc[e]))
            plcd = plcd.at[e].set(jnp.where(live, ok_f, plcd[e]))
            return (st, n_act, rr, rc, plcd), ok_f

        (state, n_active, reg_rows, reg_counts, placed), oks = jax.lax.scan(
            body, (state, n_active, reg_rows, reg_counts, placed),
            jnp.arange(idx.shape[0]))

        hall_str = pl.hall_stranding(jt, state)
        deployed = pl.deployed_kw(state)
        return (state, reg_rows, reg_counts, placed, harvested, removed,
                n_active, hall_str, deployed)

    key = jax.random.PRNGKey(cfg.seed + 1)
    n_active = jnp.asarray(1, jnp.int32)
    act_month = np.full((H,), -1, np.int64)
    act_month[0] = 0

    out = {k: [] for k in ("halls", "mw", "p50", "p90")}
    for m in range(months):
        s, e = int(starts[m]), int(ends[m])
        idx = np.arange(s, s + e_max) % E
        valid = np.arange(s, s + e_max) < e
        (state, reg_rows, reg_counts, placed, harvested, removed, n_active,
         hall_str, deployed) = step_month(
            state, reg_rows, reg_counts, placed, harvested, removed,
            n_active, jnp.asarray(m), jnp.asarray(idx), jnp.asarray(valid),
            jax.random.fold_in(key, m))
        na = int(n_active)
        newly = np.where((act_month < 0) & (np.arange(H) < na))[0]
        act_month[newly] = m

        hs = np.asarray(hall_str)
        mature = (act_month >= 0) & (act_month <= m - cfg.mature_months)
        vals = hs[mature] if mature.any() else hs[act_month >= 0]
        out["halls"].append(na)
        out["mw"].append(float(deployed) / 1e3)
        out["p50"].append(float(np.percentile(vals, 50)))
        out["p90"].append(float(np.percentile(vals, 90)))

    hs = np.asarray(pl.hall_stranding(jt, state))
    na = int(n_active)
    lineups_per_hall = topo.lineups_per_hall
    lstr = np.asarray(pl.lineup_stranding(jt, state))
    active_lineups = np.arange(lstr.shape[0]) < na * lineups_per_hall
    active_mask = np.asarray(topo.lineup_is_active) & active_lineups

    return FleetResult(
        months=np.arange(months),
        halls_active=np.asarray(out["halls"]),
        deployed_mw=np.asarray(out["mw"]),
        p50_stranding=np.asarray(out["p50"]),
        p90_stranding=np.asarray(out["p90"]),
        final_hall_stranding=hs[:na],
        final_lineup_stranding=lstr[active_mask],
        n_halls_built=na,
        final_deployed_mw=float(pl.deployed_kw(state)) / 1e3,
        placed_fraction=float(jnp.mean(placed.astype(jnp.float32))),
        design=design, env=env,
    )
