"""Fleet-scale lifecycle simulator (paper §4.4, Fig. 8 pipeline).

Places a multi-year arrival trace across a growing fleet of identical
halls: opens a new hall when no feasible placement exists (instant
commissioning, §4.2), harvests racks one year after deployment, and
decommissions racks at end-of-life.

The whole lifecycle is ONE `jax.lax.scan` over months: hall-activation
bookkeeping (`act_month`) lives in the scan carry, and the per-month
p50/p90 stranding stats are either post-hoc reductions over the scanned
`[M, H]` history (`exact_quantiles=True`, the default and regression
reference) or O(1)-memory streaming histogram estimates computed inside
the scan body (`exact_quantiles=False`, see `repro.core.quantiles`).
`simulate_lifecycle` takes only device-typed arguments, so
`sweep.py` can `vmap` it over a batch of (design, scenario, policy,
seed) configurations; `run_fleet` is the thin single-configuration
wrapper that preserves the original `FleetResult` interface.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cost, placement as pl, quantiles as qt
from .arrivals import EnvelopeSpec, Trace, generate_fleet_trace
from .hierarchy import DesignSpec, build_topology
from .placement import (DEFAULT_POLICY, Deployment, JaxTopology,
                        MAX_POD_RACKS)


@dataclass
class FleetConfig:
    design: DesignSpec
    env: EnvelopeSpec = field(default_factory=EnvelopeSpec)
    policy: int = DEFAULT_POLICY
    harvest: bool = True
    seed: int = 0
    n_halls_max: int = 0          # 0 → auto-size from demand
    mature_months: int = 12       # halls older than this enter tail stats


@dataclass
class FleetResult:
    months: np.ndarray            # [M]
    halls_active: np.ndarray      # [M]
    deployed_mw: np.ndarray       # [M]
    p50_stranding: np.ndarray     # [M] over mature halls
    p90_stranding: np.ndarray     # [M]
    final_hall_stranding: np.ndarray   # [H_active]
    final_lineup_stranding: np.ndarray  # [X_active] (active halls)
    n_halls_built: int
    final_deployed_mw: float
    placed_fraction: float
    design: DesignSpec = None
    env: EnvelopeSpec = None

    @property
    def initial_dpm(self):
        return cost.initial_dollars_per_mw(self.design)

    @property
    def effective_dpm(self):
        return cost.effective_dollars_per_mw(
            self.design, self.n_halls_built, self.final_deployed_mw)

    @property
    def total_capex(self):
        return self.n_halls_built * cost.hall_capex(self.design)


def _auto_halls(design: DesignSpec, env: EnvelopeSpec) -> int:
    # demand_multiplier() rescales cumulative demand under shock scenarios
    # (surge envelopes need more hall headroom; 1.0 for the paper grid)
    total_mw = (env.gpu_gw + env.compute_gw + env.storage_gw) * 1e3 \
        * env.demand_scale * env.demand_multiplier()
    # decommissioning returns capacity; 45% slack covers stranding + churn
    return int(np.ceil(total_mw / (design.ha_capacity_kw / 1e3) * 1.45)) + 4


class FleetTrace(NamedTuple):
    """Device-side trace columns consumed by the lifecycle scan."""
    month: jax.Array         # i32 [E]
    rack_kw: jax.Array       # f32 [E]
    n_racks: jax.Array       # i32 [E]
    is_gpu: jax.Array        # bool [E]
    is_pod: jax.Array        # bool [E]
    tier: jax.Array          # i32 [E]
    harvest_frac: jax.Array  # f32 [E]
    lifetime_m: jax.Array    # i32 [E]

    @staticmethod
    def from_trace(trace: Trace, pad_to: int | None = None,
                   pad_month: int = 0) -> "FleetTrace":
        """Pad to `pad_to` events with never-arriving placeholders
        (month = `pad_month`, which must be ≥ the simulated horizon)."""
        E = len(trace)
        n_pad = max(0, (pad_to or E) - E)

        def col(name, fill):
            a = np.asarray(getattr(trace, name))
            if n_pad:
                a = np.concatenate([a, np.full((n_pad,), fill, a.dtype)])
            return jnp.asarray(a)

        return FleetTrace(
            month=col("month", pad_month),
            rack_kw=col("rack_kw", 0.0),
            n_racks=col("n_racks", 1),
            is_gpu=col("is_gpu", False),
            is_pod=col("is_pod", False),
            tier=col("tier", 0),
            harvest_frac=col("harvest_frac", 0.0),
            lifetime_m=col("lifetime_m", 10 ** 6),
        )


def _month_e_max(trace: Trace, months: int,
                 select: np.ndarray | None = None) -> int:
    """Largest per-month event count (the inner scan length), optionally
    over the `select`-ed subset of events (split-trace pod/cluster
    windows)."""
    month = np.asarray(trace.month)
    if select is not None:
        month = month[np.asarray(select)]
    starts = np.searchsorted(month, np.arange(months))
    ends = np.searchsorted(month, np.arange(months), side="right")
    return max(1, int((ends - starts).max())) if len(month) else 1


def _month_slices(trace: Trace, months: int, e_max: int | None = None,
                  modulo: int | None = None,
                  select: np.ndarray | None = None):
    """Per-month event-index windows [M, e_max] plus validity mask.
    `modulo` must equal the (padded) device trace length.  With `select`
    (boolean event mask) the windows cover only the selected events —
    indices still refer to the full trace — which is how the split-trace
    scan gets separate pod and cluster windows per month."""
    month = np.asarray(trace.month)
    eids = None
    if select is not None:
        eids = np.flatnonzero(np.asarray(select))
        month = month[eids]
    starts = np.searchsorted(month, np.arange(months))
    ends = np.searchsorted(month, np.arange(months), side="right")
    e_max = e_max or (max(1, int((ends - starts).max()))
                      if len(month) else 1)
    pos = starts[:, None] + np.arange(e_max)[None, :]       # [M, e_max]
    valid = pos < ends[:, None]
    E = modulo or max(1, len(trace))
    if eids is None:
        idx = pos % E
    elif len(eids):
        idx = np.where(valid, eids[pos % len(eids)], 0)
    else:
        idx = np.zeros_like(pos)
    return idx.astype(np.int32), valid, e_max


def _pod_scan_len(traces) -> int:
    """Static rack-scan length for the split-trace pod path: the largest
    pod size across `traces` (capped at the `MAX_POD_RACKS` bound)."""
    n = 1
    for t in traces:
        pods = np.asarray(t.is_pod)
        if pods.any():
            n = max(n, int(np.asarray(t.n_racks)[pods].max()))
    return min(n, MAX_POD_RACKS)


def _event_windows(trace: Trace, months: int, split_pods: bool,
                   e_max: int | None = None, ep_max: int | None = None,
                   modulo: int | None = None):
    """(idx, valid, idx_pod, valid_pod) for `simulate_lifecycle`.

    `split_pods=True` partitions each month's window into pod events
    (placed first — the order generated traces already have) and cluster
    events; otherwise the first window covers all events and the pod
    window is a 1-wide all-invalid dummy (ignored by the compiled
    non-split paths).

    The split preserves placement order and PRNG keys ONLY when pods
    precede clusters within every month — always true for
    `generate_fleet_trace` output (GPU class emitted first, stable month
    sort).  Custom traces violating that order are rejected rather than
    silently reordered: sort them pods-first per month, or run with
    `legacy_pod_cond=True`."""
    if split_pods:
        pod = np.asarray(trace.is_pod)
        month = np.asarray(trace.month)
        same_month = month[1:] == month[:-1]
        if bool(np.any(same_month & pod[1:] & ~pod[:-1])):
            raise ValueError(
                "split-trace scan needs pod events to precede cluster "
                "events within each month (the generated-trace order); "
                "sort the trace pods-first per month or use "
                "legacy_pod_cond=True")
        idx, valid, _ = _month_slices(trace, months, e_max=e_max,
                                      modulo=modulo, select=~pod)
        idx_p, valid_p, _ = _month_slices(trace, months, e_max=ep_max,
                                          modulo=modulo, select=pod)
    else:
        idx, valid, _ = _month_slices(trace, months, e_max=e_max,
                                      modulo=modulo)
        idx_p = np.zeros((months, ep_max or 1), np.int32)
        valid_p = np.zeros((months, ep_max or 1), bool)
    return idx, valid, idx_p, valid_p


class SimOutputs(NamedTuple):
    """Device outputs of one lifecycle (leading batch dim under vmap)."""
    halls_active: jax.Array         # [M] i32
    deployed_kw: jax.Array          # [M] f32
    p50_stranding: jax.Array        # [M] f32
    p90_stranding: jax.Array        # [M] f32
    final_hall_stranding: jax.Array    # [H] f32
    final_lineup_stranding: jax.Array  # [X] f32
    n_halls_built: jax.Array        # [] i32
    final_deployed_kw: jax.Array    # [] f32
    placed_fraction: jax.Array      # [] f32


def _masked_percentiles(x, mask, qs):
    """np.percentile('linear') over x[mask] for each static q in `qs`
    (one shared sort); an all-False mask yields NaN (the undefined
    quantile's explicit sentinel — it used to leak the +inf sort
    padding instead)."""
    s = jnp.sort(jnp.where(mask, x, jnp.inf))
    nonempty = jnp.any(mask)
    top = (jnp.maximum(jnp.sum(mask), 1) - 1).astype(jnp.float32)
    out = []
    for q in qs:
        pos = q / 100.0 * top
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.ceil(pos).astype(jnp.int32)
        frac = pos - lo.astype(jnp.float32)
        out.append(jnp.where(nonempty,
                             s[lo] * (1.0 - frac) + s[hi] * frac,
                             jnp.nan))
    return tuple(out)


def _mature_mask(am, m, mature_months):
    """Which halls enter month `m`'s tail stats: active halls older than
    `mature_months`, falling back to all active halls while none are."""
    mature = (am >= 0) & (am <= m - mature_months)
    return jnp.where(jnp.any(mature), mature, am >= 0)


_NEW_HALL_BIAS = 1e6   # keeps placements in existing halls when feasible


def simulate_lifecycle(jt: JaxTopology, ft: FleetTrace, idx, valid,
                       idx_pod, valid_pod, policy, seed, h_cap, n_real, *,
                       harvest: bool, mature_months: int,
                       with_pods: bool = True,
                       legacy_pod_cond: bool = False,
                       pod_scan_len: int = MAX_POD_RACKS,
                       hd_scan: int | None = None,
                       use_kernel: bool = False,
                       kernel_interpret: bool = False,
                       exact_quantiles: bool = True,
                       quantile_bins: int | None = None) -> SimOutputs:
    """Run the full monthly lifecycle as a single `lax.scan`.

    All positional arguments are device-typed (vmap-able); `harvest`,
    `mature_months`, `with_pods` and `legacy_pod_cond` are static.
    `h_cap` caps hall opening per configuration (padded fleets share a
    larger static hall count).

    Placement is cost-shaped by the trace's content, because `vmap`
    evaluates both sides of every `lax.cond`:

    * `with_pods=False` (no multi-row pods): `idx`/`valid` window ALL
      events and each is placed with one biased attempt over
      `halls < n+1` — exactly equivalent to the try-then-open-a-hall
      retry for single-row clusters (a failed first attempt means no
      existing-hall row is feasible, so the biased argmin picks the same
      row either way) and roughly an order of magnitude cheaper batched.
    * `with_pods=True` (split-trace scan): each month runs TWO scans —
      `idx_pod`/`valid_pod` window the month's pod events (placed by
      `placement._place_pod` with the attempt/retry pair, which pods
      genuinely need: a pod that fails in existing halls must retry
      whole against the new hall), then `idx`/`valid` window the
      cluster events (cheap biased attempt).  Cluster events no longer
      pay for the 8-step pod scan and pods no longer pay for the
      cluster branch.  Trace order is preserved because generated
      traces emit pods before clusters within every month (GPU class
      first, stable month sort); PRNG keys stay aligned with the
      interleaved order via the per-month pod-count offset.
      `pod_scan_len` (static, ≥ the largest pod's `n_racks`) trims the
      rack scan to the batch's real max pod size instead of the
      `MAX_POD_RACKS` bound, and `hd_scan` (static, ≥ the batch's
      HD-row count) restricts each pod rack's row search to the
      compacted HD view `jt.hd_index[:hd_scan]` — GPU pods are HD-only,
      so the trim is bitwise inert (see `placement._place_pod`).
    * `legacy_pod_cond=True` (benchmark/regression reference): the
      pre-split behavior — `idx`/`valid` window ALL events and each one
      runs `placement.place`'s `lax.cond(is_pod, …)` plus the retry
      `lax.cond`, evaluating both pod and cluster branches per event
      under `vmap`.  `benchmarks/run.py --only pod_sweep_speedup`
      measures the split-trace win against exactly this path.

    `use_kernel` / `kernel_interpret` (static) route every placement's
    feasibility + variance score through the fused Pallas kernel
    (bitwise-identical results; see `placement.place_in_row`).

    `exact_quantiles` (static) selects the p50/p90 stranding path:

    * `True` (default, the regression reference — the `legacy_pod_cond`
      pattern): the scan emits the full `[M, H]` stranding/activation
      history and the percentiles are post-hoc `_masked_percentiles`
      reductions — exact, but O(M·H) memory per configuration.
    * `False` (streaming): each month's `[H]` stranding cross-section is
      folded into a `quantile_bins`-bucket histogram estimate *inside
      the scan body* (`quantiles.hist_masked_quantiles`), so the scan
      emits two scalars per month and no `[M, H]` history is ever
      materialized — O(1) stats memory per configuration, absolute
      error ≤ `1 / quantile_bins` (default `quantiles.DEFAULT_BINS`,
      512 → ≤ 0.2%).  This is the path giant grids compile
      (`benchmarks/run.py --only giant_grid`).
    """
    H = jt.hall_liq_cap.shape[0]
    E = ft.month.shape[0]
    M = idx.shape[0]
    split_pods = with_pods and not legacy_pod_cond
    n_bins = quantile_bins or qt.DEFAULT_BINS

    state = pl.init_state_from(jt)
    reg_rows = jnp.full((E, MAX_POD_RACKS), -1, jnp.int32)
    reg_counts = jnp.zeros((E, MAX_POD_RACKS), jnp.float32)
    placed = jnp.zeros((E,), bool)
    harvested = jnp.zeros((E,), bool)
    removed = jnp.zeros((E,), bool)
    n_active = jnp.asarray(1, jnp.int32)
    act_month = jnp.full((H,), -1, jnp.int32).at[0].set(0)
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.int32) + 1)
    policy = jnp.asarray(policy, jnp.int32)
    h_cap = jnp.asarray(h_cap, jnp.int32)

    # ---- placement modes (see docstring) ----
    def place_cluster(st, n_act, dep, k, n_try):
        """One biased attempt over halls < n_try (single-row clusters)."""
        bias = jnp.where(jt.row_hall >= n_act, _NEW_HALL_BIAS, 0.0)
        st_f, ok_f, rows_f, counts_f, row = pl.place_cluster_in_row(
            jt, st, dep, policy, k, jt.row_hall < n_try, score_bias=bias,
            use_kernel=use_kernel, interpret=kernel_interpret)
        in_existing = ok_f & (jt.row_hall[jnp.maximum(row, 0)] < n_act)
        n_f = jnp.where(in_existing, n_act, n_try)
        return st_f, ok_f, rows_f, counts_f, n_f

    def place_pod(st, n_act, dep, k, n_try):
        """Pod attempt in existing halls, whole-pod retry incl. the new
        hall (pods need the atomic retry: a partial fit must not lock a
        domain the full pod cannot share)."""
        st1, ok1, rows1, counts1 = pl._place_pod(jt, st, dep, policy, k,
                                                 jt.row_hall < n_act,
                                                 max_racks=pod_scan_len,
                                                 hd_scan=hd_scan,
                                                 use_kernel=use_kernel,
                                                 interpret=kernel_interpret)

        def retry():
            st2, ok2, rows2, counts2 = pl._place_pod(
                jt, st, dep, policy, k, jt.row_hall < n_try,
                max_racks=pod_scan_len, hd_scan=hd_scan,
                use_kernel=use_kernel, interpret=kernel_interpret)
            return st2, ok2, rows2, counts2, n_try

        return jax.lax.cond(
            ok1, lambda: (st1, ok1, rows1, counts1, n_act), retry)

    def place_any(st, n_act, dep, k, n_try):
        """Pre-split reference: `place`'s is_pod cond + attempt/retry."""
        def attempt(n):
            return pl.place(jt, st, dep, policy, k, jt.row_hall < n,
                            use_kernel=use_kernel,
                            interpret=kernel_interpret)

        st1, ok1, rows1, counts1 = attempt(n_act)

        def retry():
            st2, ok2, rows2, counts2 = attempt(n_try)
            return st2, ok2, rows2, counts2, n_try

        return jax.lax.cond(
            ok1, lambda: (st1, ok1, rows1, counts1, n_act), retry)

    def scan_events(carry, idx_m, valid_m, mkey, key_off, place_fn):
        """Inner event scan shared by every mode.  `key_off` keeps the
        per-event fold_in keys aligned with the interleaved event order
        when a month is split into pod + cluster scans."""
        def body(carry, i):
            st, n_act, rr, rc, plcd = carry
            e = idx_m[i]
            dep = Deployment(ft.rack_kw[e], ft.n_racks[e], ft.is_gpu[e],
                             ft.tier[e], ft.is_pod[e])
            k = jax.random.fold_in(mkey, key_off + i)
            n_try = jnp.minimum(n_act + 1, h_cap)
            st_f, ok_f, rows_f, counts_f, n_f = place_fn(st, n_act, dep,
                                                         k, n_try)
            live = valid_m[i]
            ok_f = ok_f & live
            st = pl._tree_where(ok_f, st_f, st)
            n_act = jnp.where(live, n_f, n_act)
            rr = rr.at[e].set(jnp.where(ok_f, rows_f, rr[e]))
            rc = rc.at[e].set(jnp.where(ok_f, counts_f, rc[e]))
            plcd = plcd.at[e].set(jnp.where(live, ok_f, plcd[e]))
            return (st, n_act, rr, rc, plcd), None

        return jax.lax.scan(body, carry,
                            jnp.arange(idx_m.shape[0]))[0]

    def month_step(carry, xs):
        (state, reg_rows, reg_counts, placed, harvested, removed,
         n_active, act_month) = carry
        m, idx_m, valid_m, idx_pod_m, valid_pod_m = xs
        mkey = jax.random.fold_in(key, m)

        # ---- 1. decommission expired racks ----
        expire = placed & ~removed & (ft.month + ft.lifetime_m <= m)
        frac_dec = jnp.where(
            expire, 1.0 - jnp.where(harvested, ft.harvest_frac, 0.0), 0.0)
        state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                ft.rack_kw, ft.is_gpu, ft.tier, frac_dec)
        removed = removed | expire

        # ---- 2. harvest one-year-old racks ----
        if harvest:
            h = placed & ~removed & ~harvested & (ft.month + 12 <= m)
            state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                    ft.rack_kw, ft.is_gpu, ft.tier,
                                    jnp.where(h, ft.harvest_frac, 0.0))
            harvested = harvested | h

        # ---- 3. place this month's arrivals ----
        pcarry = (state, n_active, reg_rows, reg_counts, placed)
        if split_pods:
            # pods first (the generated order), then clusters with the
            # fold_in offset continuing where the pod window left off
            pcarry = scan_events(pcarry, idx_pod_m, valid_pod_m, mkey,
                                 jnp.zeros((), jnp.int32), place_pod)
            n_pods = jnp.sum(valid_pod_m.astype(jnp.int32))
            pcarry = scan_events(pcarry, idx_m, valid_m, mkey, n_pods,
                                 place_cluster)
        elif with_pods:
            pcarry = scan_events(pcarry, idx_m, valid_m, mkey,
                                 jnp.zeros((), jnp.int32), place_any)
        else:
            pcarry = scan_events(pcarry, idx_m, valid_m, mkey,
                                 jnp.zeros((), jnp.int32), place_cluster)
        state, n_active, reg_rows, reg_counts, placed = pcarry

        act_month = jnp.where(
            (act_month < 0) & (jnp.arange(H) < n_active), m, act_month)
        carry = (state, reg_rows, reg_counts, placed, harvested, removed,
                 n_active, act_month)
        hs_m = pl.hall_stranding(jt, state)
        if exact_quantiles:
            ys = (hs_m, act_month)
        else:
            ys = qt.hist_masked_quantiles(
                hs_m, _mature_mask(act_month, m, mature_months),
                (50.0, 90.0), n_bins=n_bins)
        return carry, (n_active, pl.deployed_kw(state)) + ys

    carry0 = (state, reg_rows, reg_counts, placed, harvested, removed,
              n_active, act_month)
    xs = (jnp.arange(M, dtype=jnp.int32), jnp.asarray(idx),
          jnp.asarray(valid), jnp.asarray(idx_pod),
          jnp.asarray(valid_pod))
    carry, (halls, deployed, y3, y4) = jax.lax.scan(
        month_step, carry0, xs)
    state, placed = carry[0], carry[3]

    if exact_quantiles:
        # ---- post-hoc percentile reductions over the scanned history ----
        def stats(hs, am, m):
            return _masked_percentiles(
                hs, _mature_mask(am, m, mature_months), (50.0, 90.0))

        p50, p90 = jax.vmap(stats)(y3, y4,
                                   jnp.arange(M, dtype=jnp.int32))
    else:
        p50, p90 = y3, y4

    # padding events are never placed, so the sum counts only real events
    pf = jnp.sum(placed.astype(jnp.float32)) / \
        jnp.maximum(jnp.asarray(n_real, jnp.float32), 1.0)
    return SimOutputs(
        halls_active=halls, deployed_kw=deployed,
        p50_stranding=p50, p90_stranding=p90,
        final_hall_stranding=pl.hall_stranding(jt, state),
        final_lineup_stranding=pl.lineup_stranding(jt, state),
        n_halls_built=carry[6], final_deployed_kw=pl.deployed_kw(state),
        placed_fraction=pf)


@functools.partial(jax.jit,
                   static_argnames=("harvest", "mature_months", "with_pods",
                                    "legacy_pod_cond", "pod_scan_len",
                                    "hd_scan", "use_kernel",
                                    "kernel_interpret", "exact_quantiles",
                                    "quantile_bins"))
def _simulate_jit(jt, ft, idx, valid, idx_pod, valid_pod, policy, seed,
                  h_cap, n_real, harvest, mature_months, with_pods,
                  legacy_pod_cond=False, pod_scan_len=MAX_POD_RACKS,
                  hd_scan=None, use_kernel=False, kernel_interpret=False,
                  exact_quantiles=True, quantile_bins=None):
    return simulate_lifecycle(jt, ft, idx, valid, idx_pod, valid_pod,
                              policy, seed, h_cap, n_real, harvest=harvest,
                              mature_months=mature_months,
                              with_pods=with_pods,
                              legacy_pod_cond=legacy_pod_cond,
                              pod_scan_len=pod_scan_len, hd_scan=hd_scan,
                              use_kernel=use_kernel,
                              kernel_interpret=kernel_interpret,
                              exact_quantiles=exact_quantiles,
                              quantile_bins=quantile_bins)


def make_fleet_result(out, months: int, lineups_per_hall: int,
                      lineup_is_active: np.ndarray, design: DesignSpec,
                      env: EnvelopeSpec) -> FleetResult:
    """Host-side unpack of (per-configuration) `SimOutputs` into the
    public `FleetResult` (shared by `run_fleet` and `sweep.result`)."""
    na = int(out.n_halls_built)
    hs = np.asarray(out.final_hall_stranding)
    lstr = np.asarray(out.final_lineup_stranding)
    active_lineups = np.arange(lstr.shape[0]) // lineups_per_hall < na
    active_mask = np.asarray(lineup_is_active) & active_lineups
    return FleetResult(
        months=np.arange(months),
        halls_active=np.asarray(out.halls_active),
        deployed_mw=np.asarray(out.deployed_kw) / 1e3,
        p50_stranding=np.asarray(out.p50_stranding),
        p90_stranding=np.asarray(out.p90_stranding),
        final_hall_stranding=hs[:na],
        final_lineup_stranding=lstr[active_mask],
        n_halls_built=na,
        final_deployed_mw=float(out.final_deployed_kw) / 1e3,
        placed_fraction=float(out.placed_fraction),
        design=design, env=env,
    )


def run_fleet(cfg: FleetConfig, trace: Trace | None = None,
              use_kernel: bool | None = None,
              kernel_interpret: bool = False,
              exact_quantiles: bool = True,
              quantile_bins: int | None = None) -> FleetResult:
    """Single-configuration lifecycle (thin wrapper over the scanned
    engine).

    Builds the hall topology at its *exact* shape (no sweep padding),
    generates (or takes) the arrival trace, and runs the jitted
    `simulate_lifecycle` scan once.  This is the reference semantics the
    sweep engine is tested against: for a grid of configurations use
    `repro.core.sweep.sweep` (one vmapped call) or
    `repro.core.sweep.sharded_sweep` (vmapped + sharded over devices),
    whose `result(i)` reproduces this function's `FleetResult` up to
    float-padding noise.

    Args:
        cfg: design/envelope/policy/seed bundle (see `FleetConfig`).
        trace: optional pre-generated arrival trace; defaults to
            `generate_fleet_trace(cfg.env, cfg.seed)`.
        use_kernel: route placement scoring through the fused Pallas
            kernel (bitwise-identical results); `None` = backend default
            (`placement.default_use_kernel`: TPU on, CPU off).
        kernel_interpret: run the kernel in Pallas interpret mode (CPU
            CI fallback; only meaningful with the kernel path on).
        exact_quantiles: `True` (default) computes p50/p90 stranding as
            the exact post-hoc reduction over the `[M, H]` history;
            `False` compiles the O(1)-memory streaming histogram path
            (error ≤ `1 / quantile_bins`; see `simulate_lifecycle`).
        quantile_bins: streaming-histogram resolution (default
            `quantiles.DEFAULT_BINS`); ignored when exact.

    Returns:
        `FleetResult` with monthly [M] trajectories (halls active,
        deployed MW, p50/p90 mature-hall stranding), final per-hall
        [n_halls_built] and per-active-line-up stranding, and the cost
        roll-ups (`initial_dpm`, `effective_dpm`, `total_capex`).
    """
    design, env = cfg.design, cfg.env
    if trace is None:
        trace = generate_fleet_trace(env, cfg.seed)
    months = env.n_months
    H = cfg.n_halls_max or _auto_halls(design, env)
    topo = build_topology(design, H)
    jt = pl.jax_topology(topo)
    ft = FleetTrace.from_trace(trace)
    with_pods = bool(np.asarray(trace.is_pod).any())
    idx, valid, idx_p, valid_p = _event_windows(trace, months, with_pods)

    out = _simulate_jit(jt, ft, jnp.asarray(idx), jnp.asarray(valid),
                        jnp.asarray(idx_p), jnp.asarray(valid_p),
                        jnp.asarray(cfg.policy, jnp.int32),
                        jnp.asarray(cfg.seed, jnp.int32),
                        jnp.asarray(H, jnp.int32),
                        jnp.asarray(len(trace), jnp.int32),
                        harvest=cfg.harvest,
                        mature_months=cfg.mature_months,
                        with_pods=with_pods,
                        pod_scan_len=_pod_scan_len([trace]),
                        hd_scan=topo.n_hd_rows,
                        use_kernel=pl.resolve_use_kernel(use_kernel),
                        kernel_interpret=kernel_interpret,
                        exact_quantiles=exact_quantiles,
                        quantile_bins=quantile_bins)
    return make_fleet_result(out, months, topo.lineups_per_hall,
                             topo.lineup_is_active, design, env)
