"""Fleet-scale lifecycle simulator (paper §4.4, Fig. 8 pipeline).

Places a multi-year arrival trace across a growing fleet of identical
halls: opens a new hall when no feasible placement exists (instant
commissioning, §4.2), harvests racks one year after deployment, and
decommissions racks at end-of-life.

The whole lifecycle is ONE `jax.lax.scan` over months: hall-activation
bookkeeping (`act_month`) lives in the scan carry, and the per-month
p50/p90 stranding stats are post-hoc reductions over the scanned
history.  `simulate_lifecycle` takes only device-typed arguments, so
`sweep.py` can `vmap` it over a batch of (design, scenario, policy,
seed) configurations; `run_fleet` is the thin single-configuration
wrapper that preserves the original `FleetResult` interface.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cost, placement as pl
from .arrivals import EnvelopeSpec, Trace, generate_fleet_trace
from .hierarchy import DesignSpec, build_topology
from .placement import (DEFAULT_POLICY, Deployment, JaxTopology,
                        MAX_POD_RACKS)


@dataclass
class FleetConfig:
    design: DesignSpec
    env: EnvelopeSpec = field(default_factory=EnvelopeSpec)
    policy: int = DEFAULT_POLICY
    harvest: bool = True
    seed: int = 0
    n_halls_max: int = 0          # 0 → auto-size from demand
    mature_months: int = 12       # halls older than this enter tail stats


@dataclass
class FleetResult:
    months: np.ndarray            # [M]
    halls_active: np.ndarray      # [M]
    deployed_mw: np.ndarray       # [M]
    p50_stranding: np.ndarray     # [M] over mature halls
    p90_stranding: np.ndarray     # [M]
    final_hall_stranding: np.ndarray   # [H_active]
    final_lineup_stranding: np.ndarray  # [X_active] (active halls)
    n_halls_built: int
    final_deployed_mw: float
    placed_fraction: float
    design: DesignSpec = None
    env: EnvelopeSpec = None

    @property
    def initial_dpm(self):
        return cost.initial_dollars_per_mw(self.design)

    @property
    def effective_dpm(self):
        return cost.effective_dollars_per_mw(
            self.design, self.n_halls_built, self.final_deployed_mw)

    @property
    def total_capex(self):
        return self.n_halls_built * cost.hall_capex(self.design)


def _auto_halls(design: DesignSpec, env: EnvelopeSpec) -> int:
    # demand_multiplier() rescales cumulative demand under shock scenarios
    # (surge envelopes need more hall headroom; 1.0 for the paper grid)
    total_mw = (env.gpu_gw + env.compute_gw + env.storage_gw) * 1e3 \
        * env.demand_scale * env.demand_multiplier()
    # decommissioning returns capacity; 45% slack covers stranding + churn
    return int(np.ceil(total_mw / (design.ha_capacity_kw / 1e3) * 1.45)) + 4


class FleetTrace(NamedTuple):
    """Device-side trace columns consumed by the lifecycle scan."""
    month: jax.Array         # i32 [E]
    rack_kw: jax.Array       # f32 [E]
    n_racks: jax.Array       # i32 [E]
    is_gpu: jax.Array        # bool [E]
    is_pod: jax.Array        # bool [E]
    tier: jax.Array          # i32 [E]
    harvest_frac: jax.Array  # f32 [E]
    lifetime_m: jax.Array    # i32 [E]

    @staticmethod
    def from_trace(trace: Trace, pad_to: int | None = None,
                   pad_month: int = 0) -> "FleetTrace":
        """Pad to `pad_to` events with never-arriving placeholders
        (month = `pad_month`, which must be ≥ the simulated horizon)."""
        E = len(trace)
        n_pad = max(0, (pad_to or E) - E)

        def col(name, fill):
            a = np.asarray(getattr(trace, name))
            if n_pad:
                a = np.concatenate([a, np.full((n_pad,), fill, a.dtype)])
            return jnp.asarray(a)

        return FleetTrace(
            month=col("month", pad_month),
            rack_kw=col("rack_kw", 0.0),
            n_racks=col("n_racks", 1),
            is_gpu=col("is_gpu", False),
            is_pod=col("is_pod", False),
            tier=col("tier", 0),
            harvest_frac=col("harvest_frac", 0.0),
            lifetime_m=col("lifetime_m", 10 ** 6),
        )


def _month_e_max(trace: Trace, months: int) -> int:
    """Largest per-month event count (the inner scan length)."""
    starts = np.searchsorted(trace.month, np.arange(months))
    ends = np.searchsorted(trace.month, np.arange(months), side="right")
    return max(1, int((ends - starts).max()))


def _month_slices(trace: Trace, months: int, e_max: int | None = None,
                  modulo: int | None = None):
    """Per-month event-index windows [M, e_max] plus validity mask.
    `modulo` must equal the (padded) device trace length."""
    starts = np.searchsorted(trace.month, np.arange(months))
    ends = np.searchsorted(trace.month, np.arange(months), side="right")
    e_max = e_max or max(1, int((ends - starts).max()))
    idx = starts[:, None] + np.arange(e_max)[None, :]       # [M, e_max]
    valid = idx < ends[:, None]
    E = modulo or max(1, len(trace))
    return (idx % E).astype(np.int32), valid, e_max


class SimOutputs(NamedTuple):
    """Device outputs of one lifecycle (leading batch dim under vmap)."""
    halls_active: jax.Array         # [M] i32
    deployed_kw: jax.Array          # [M] f32
    p50_stranding: jax.Array        # [M] f32
    p90_stranding: jax.Array        # [M] f32
    final_hall_stranding: jax.Array    # [H] f32
    final_lineup_stranding: jax.Array  # [X] f32
    n_halls_built: jax.Array        # [] i32
    final_deployed_kw: jax.Array    # [] f32
    placed_fraction: jax.Array      # [] f32


def _masked_percentiles(x, mask, qs):
    """np.percentile('linear') over x[mask] for each static q in `qs`
    (one shared sort); needs ≥1 masked element."""
    s = jnp.sort(jnp.where(mask, x, jnp.inf))
    top = (jnp.maximum(jnp.sum(mask), 1) - 1).astype(jnp.float32)
    out = []
    for q in qs:
        pos = q / 100.0 * top
        lo = jnp.floor(pos).astype(jnp.int32)
        hi = jnp.ceil(pos).astype(jnp.int32)
        frac = pos - lo.astype(jnp.float32)
        out.append(s[lo] * (1.0 - frac) + s[hi] * frac)
    return tuple(out)


_NEW_HALL_BIAS = 1e6   # keeps placements in existing halls when feasible


def simulate_lifecycle(jt: JaxTopology, ft: FleetTrace, idx, valid, policy,
                       seed, h_cap, n_real, *, harvest: bool,
                       mature_months: int,
                       with_pods: bool = True) -> SimOutputs:
    """Run the full monthly lifecycle as a single `lax.scan`.

    All positional arguments are device-typed (vmap-able); `harvest`,
    `mature_months` and `with_pods` are static.  `h_cap` caps hall
    opening per configuration (padded fleets share a larger static hall
    count).  `with_pods=False` (trace has no multi-row pods) replaces the
    try-then-open-a-hall retry with one biased placement attempt over
    `halls < n+1` — exactly equivalent for single-row clusters (a failed
    first attempt means no existing-hall row is feasible, so the biased
    argmin picks the same row either way) and roughly an order of
    magnitude cheaper under `vmap`, where `lax.cond` runs both branches.
    """
    H = jt.hall_liq_cap.shape[0]
    E = ft.month.shape[0]
    M = idx.shape[0]

    state = pl.init_state_from(jt)
    reg_rows = jnp.full((E, MAX_POD_RACKS), -1, jnp.int32)
    reg_counts = jnp.zeros((E, MAX_POD_RACKS), jnp.float32)
    placed = jnp.zeros((E,), bool)
    harvested = jnp.zeros((E,), bool)
    removed = jnp.zeros((E,), bool)
    n_active = jnp.asarray(1, jnp.int32)
    act_month = jnp.full((H,), -1, jnp.int32).at[0].set(0)
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.int32) + 1)
    policy = jnp.asarray(policy, jnp.int32)
    h_cap = jnp.asarray(h_cap, jnp.int32)

    def month_step(carry, xs):
        (state, reg_rows, reg_counts, placed, harvested, removed,
         n_active, act_month) = carry
        m, idx_m, valid_m = xs
        mkey = jax.random.fold_in(key, m)

        # ---- 1. decommission expired racks ----
        expire = placed & ~removed & (ft.month + ft.lifetime_m <= m)
        frac_dec = jnp.where(
            expire, 1.0 - jnp.where(harvested, ft.harvest_frac, 0.0), 0.0)
        state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                ft.rack_kw, ft.is_gpu, ft.tier, frac_dec)
        removed = removed | expire

        # ---- 2. harvest one-year-old racks ----
        if harvest:
            h = placed & ~removed & ~harvested & (ft.month + 12 <= m)
            state = pl.release_bulk(jt, state, reg_rows, reg_counts,
                                    ft.rack_kw, ft.is_gpu, ft.tier,
                                    jnp.where(h, ft.harvest_frac, 0.0))
            harvested = harvested | h

        # ---- 3. place this month's arrivals ----
        def body(carry, i):
            st, n_act, rr, rc, plcd = carry
            e = idx_m[i]
            dep = Deployment(ft.rack_kw[e], ft.n_racks[e], ft.is_gpu[e],
                             ft.tier[e], ft.is_pod[e])
            k = jax.random.fold_in(mkey, i)
            n_try = jnp.minimum(n_act + 1, h_cap)

            if with_pods:
                # perf: under vmap this lax.cond evaluates BOTH branches
                # (first attempt AND the open-a-hall retry) for every
                # batched configuration; a split-trace (pods vs clusters)
                # scan would cut pod sweeps ~2x — see ROADMAP.md
                # "Pod-path cost under vmap".
                def attempt(n):
                    return pl.place(jt, st, dep, policy, k, jt.row_hall < n)

                st1, ok1, rows1, counts1 = attempt(n_act)

                def retry():
                    st2, ok2, rows2, counts2 = attempt(n_try)
                    return st2, ok2, rows2, counts2, n_try

                st_f, ok_f, rows_f, counts_f, n_f = jax.lax.cond(
                    ok1, lambda: (st1, ok1, rows1, counts1, n_act), retry)
            else:
                bias = jnp.where(jt.row_hall >= n_act, _NEW_HALL_BIAS, 0.0)
                st_f, ok_f, row = pl.place_in_row(
                    jt, st, dep, dep.n_racks, policy, k,
                    jt.row_hall < n_try, score_bias=bias)
                rows_f = jnp.full((MAX_POD_RACKS,), -1, jnp.int32
                                  ).at[0].set(row)
                counts_f = jnp.zeros((MAX_POD_RACKS,)).at[0].set(
                    jnp.where(ok_f, dep.n_racks.astype(jnp.float32), 0.0))
                in_existing = ok_f & (jt.row_hall[jnp.maximum(row, 0)]
                                      < n_act)
                n_f = jnp.where(in_existing, n_act, n_try)

            live = valid_m[i]
            ok_f = ok_f & live
            st = pl._tree_where(ok_f, st_f, st)
            n_act = jnp.where(live, n_f, n_act)
            rr = rr.at[e].set(jnp.where(ok_f, rows_f, rr[e]))
            rc = rc.at[e].set(jnp.where(ok_f, counts_f, rc[e]))
            plcd = plcd.at[e].set(jnp.where(live, ok_f, plcd[e]))
            return (st, n_act, rr, rc, plcd), None

        (state, n_active, reg_rows, reg_counts, placed), _ = jax.lax.scan(
            body, (state, n_active, reg_rows, reg_counts, placed),
            jnp.arange(idx_m.shape[0]))

        act_month = jnp.where(
            (act_month < 0) & (jnp.arange(H) < n_active), m, act_month)
        carry = (state, reg_rows, reg_counts, placed, harvested, removed,
                 n_active, act_month)
        return carry, (n_active, pl.deployed_kw(state),
                       pl.hall_stranding(jt, state), act_month)

    carry0 = (state, reg_rows, reg_counts, placed, harvested, removed,
              n_active, act_month)
    xs = (jnp.arange(M, dtype=jnp.int32), jnp.asarray(idx),
          jnp.asarray(valid))
    carry, (halls, deployed, hs_hist, am_hist) = jax.lax.scan(
        month_step, carry0, xs)
    state, placed = carry[0], carry[3]

    # ---- post-hoc percentile reductions over the scanned history ----
    def stats(hs, am, m):
        mature = (am >= 0) & (am <= m - mature_months)
        mask = jnp.where(jnp.any(mature), mature, am >= 0)
        return _masked_percentiles(hs, mask, (50.0, 90.0))

    p50, p90 = jax.vmap(stats)(hs_hist, am_hist,
                               jnp.arange(M, dtype=jnp.int32))

    # padding events are never placed, so the sum counts only real events
    pf = jnp.sum(placed.astype(jnp.float32)) / \
        jnp.maximum(jnp.asarray(n_real, jnp.float32), 1.0)
    return SimOutputs(
        halls_active=halls, deployed_kw=deployed,
        p50_stranding=p50, p90_stranding=p90,
        final_hall_stranding=pl.hall_stranding(jt, state),
        final_lineup_stranding=pl.lineup_stranding(jt, state),
        n_halls_built=carry[6], final_deployed_kw=pl.deployed_kw(state),
        placed_fraction=pf)


@functools.partial(jax.jit,
                   static_argnames=("harvest", "mature_months", "with_pods"))
def _simulate_jit(jt, ft, idx, valid, policy, seed, h_cap, n_real,
                  harvest, mature_months, with_pods):
    return simulate_lifecycle(jt, ft, idx, valid, policy, seed, h_cap,
                              n_real, harvest=harvest,
                              mature_months=mature_months,
                              with_pods=with_pods)


def make_fleet_result(out, months: int, lineups_per_hall: int,
                      lineup_is_active: np.ndarray, design: DesignSpec,
                      env: EnvelopeSpec) -> FleetResult:
    """Host-side unpack of (per-configuration) `SimOutputs` into the
    public `FleetResult` (shared by `run_fleet` and `sweep.result`)."""
    na = int(out.n_halls_built)
    hs = np.asarray(out.final_hall_stranding)
    lstr = np.asarray(out.final_lineup_stranding)
    active_lineups = np.arange(lstr.shape[0]) // lineups_per_hall < na
    active_mask = np.asarray(lineup_is_active) & active_lineups
    return FleetResult(
        months=np.arange(months),
        halls_active=np.asarray(out.halls_active),
        deployed_mw=np.asarray(out.deployed_kw) / 1e3,
        p50_stranding=np.asarray(out.p50_stranding),
        p90_stranding=np.asarray(out.p90_stranding),
        final_hall_stranding=hs[:na],
        final_lineup_stranding=lstr[active_mask],
        n_halls_built=na,
        final_deployed_mw=float(out.final_deployed_kw) / 1e3,
        placed_fraction=float(out.placed_fraction),
        design=design, env=env,
    )


def run_fleet(cfg: FleetConfig, trace: Trace | None = None) -> FleetResult:
    """Single-configuration lifecycle (thin wrapper over the scanned
    engine).

    Builds the hall topology at its *exact* shape (no sweep padding),
    generates (or takes) the arrival trace, and runs the jitted
    `simulate_lifecycle` scan once.  This is the reference semantics the
    sweep engine is tested against: for a grid of configurations use
    `repro.core.sweep.sweep` (one vmapped call) or
    `repro.core.sweep.sharded_sweep` (vmapped + sharded over devices),
    whose `result(i)` reproduces this function's `FleetResult` up to
    float-padding noise.

    Args:
        cfg: design/envelope/policy/seed bundle (see `FleetConfig`).
        trace: optional pre-generated arrival trace; defaults to
            `generate_fleet_trace(cfg.env, cfg.seed)`.

    Returns:
        `FleetResult` with monthly [M] trajectories (halls active,
        deployed MW, p50/p90 mature-hall stranding), final per-hall
        [n_halls_built] and per-active-line-up stranding, and the cost
        roll-ups (`initial_dpm`, `effective_dpm`, `total_capex`).
    """
    design, env = cfg.design, cfg.env
    if trace is None:
        trace = generate_fleet_trace(env, cfg.seed)
    months = env.n_months
    H = cfg.n_halls_max or _auto_halls(design, env)
    topo = build_topology(design, H)
    jt = pl.jax_topology(topo)
    ft = FleetTrace.from_trace(trace)
    idx, valid, _ = _month_slices(trace, months)

    out = _simulate_jit(jt, ft, jnp.asarray(idx), jnp.asarray(valid),
                        jnp.asarray(cfg.policy, jnp.int32),
                        jnp.asarray(cfg.seed, jnp.int32),
                        jnp.asarray(H, jnp.int32),
                        jnp.asarray(len(trace), jnp.int32),
                        harvest=cfg.harvest,
                        mature_months=cfg.mature_months,
                        with_pods=bool(np.asarray(trace.is_pod).any()))
    return make_fleet_result(out, months, topo.lineups_per_hall,
                             topo.lineup_is_active, design, env)
