"""Batched single-hall Monte Carlo engine (paper §4.4, Figs. 5–7).

The paper's single-hall results are grids — stranding CDFs per design
(Fig. 5), a 21-point single-SKU kW sweep per design (Fig. 6), a policy
comparison (Fig. 7) — yet `singlehall.monte_carlo` used to be called
once per grid point, each call synthesizing its trial traces in a
host-side Python loop.  This module is the sweep-style front end: trial
*generation* is one vectorized numpy pass (`arrivals.sample_mixed_traces`)
and trial *evaluation* is ONE jitted call that vmaps
`singlehall.run_trial` over the whole (configuration × trial) grid, with
topologies padded to common shapes exactly like `sweep.SweepAxes`:

    axes = MCAxes.product(designs=[get_design("4N/3"), get_design("3+1")],
                          sku_kw=np.arange(200, 2501, 115))
    res = mc_sweep(axes, n_trials=4, n_events=300,
                   harvest=False, single_sku_gpu=True)   # one compiled call
    res.deployed_kw[i].mean(), res.result(i) ...

On a multi-device host, `sharded_mc_sweep` splits the (config × trial)
grid over the same named 2-D (config × trial) mesh the fleet sweep uses
(`repro.sharding.axes.sweep_mesh`): flattened and product-sharded by
default (bitwise the historical 1-D `CONFIG_AXIS` layout on a (D, 1)
mesh), or block-sharded as a true [B, T] grid with
`mesh_shape=(dc, dt)` so topologies ship once per configuration;
trials are independent, so sharded and single-device results agree to
float tolerance and one device is a passthrough.
`singlehall.monte_carlo` remains the exact one-configuration wrapper.
"""
from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from . import arrivals, cost, placement as pl, projections as proj
from . import throughput as tp
from .hierarchy import (DesignSpec, HallTopology, SweepValidationError,
                        build_topology)
from .placement import DEFAULT_POLICY, POLICY_NAMES, JaxTopology
from .singlehall import TraceArrays, run_trial
from repro.sharding import axes as shax
from .sweep import _broadcast


@dataclass
class MCAxes:
    """The single-hall configuration batch `mc_sweep` vmaps over.

    Four aligned per-configuration lists of equal length ``B``:
    configuration ``i`` is ``(designs[i], sku_kw[i], policies[i],
    seeds[i])``, where `sku_kw` is the optional Fig. 6 GPU SKU-kW
    override (None = empirical SKU mix).  Length-1 lists broadcast, and
    `tags` rides along for reporting exactly like `sweep.SweepAxes.tags`.

    Trial count, event count, year/scenario and the other trace-stream
    parameters are *call-level* arguments of `mc_sweep` (they set static
    array shapes / generator behavior shared by the whole grid).
    """
    designs: List[DesignSpec]
    sku_kw: List[Optional[float]] = field(default_factory=lambda: [None])
    policies: List[int] = field(default_factory=lambda: [DEFAULT_POLICY])
    seeds: List[int] = field(default_factory=lambda: [0])
    tags: List[str] = field(default_factory=lambda: [""])

    def __len__(self):
        return len(self.designs)

    def __post_init__(self):
        B = max(len(self.designs), len(self.sku_kw), len(self.policies),
                len(self.seeds), len(self.tags))
        self.designs = _broadcast(self.designs, B, "designs")
        self.sku_kw = [None if k is None else float(k)
                       for k in _broadcast(self.sku_kw, B, "sku_kw")]
        self.policies = [int(p) for p in _broadcast(self.policies, B,
                                                    "policies")]
        self.seeds = [int(s) for s in _broadcast(self.seeds, B, "seeds")]
        self.tags = [str(t) for t in _broadcast(self.tags, B, "tags")]

    @staticmethod
    def zip(designs, sku_kw=(None,), policies=(DEFAULT_POLICY,), seeds=(0,),
            tags=("",)) -> "MCAxes":
        """Aligned per-configuration sequences (length-1 broadcasts)."""
        return MCAxes(list(designs), list(sku_kw), list(policies),
                      list(seeds), list(tags))

    @staticmethod
    def product(designs: Sequence[DesignSpec],
                sku_kw: Sequence[Optional[float]] = (None,),
                policies: Sequence[int] = (DEFAULT_POLICY,),
                seeds: Sequence[int] = (0,),
                tags: Sequence[str] | None = None) -> "MCAxes":
        """Full grid, designs-major ordering (seeds vary fastest).

        `tags` (aligned with `designs`, length-1 broadcasts) labels each
        design and follows it through the cross product — the `MCAxes`
        analogue of `SweepAxes.product(env_tags=…)`."""
        tags = _broadcast(tags, len(designs), "tags") \
            if tags is not None else [""] * len(designs)
        combos = list(itertools.product(zip(designs, tags), sku_kw,
                                        policies, seeds))
        return MCAxes([c[0][0] for c in combos], [c[1] for c in combos],
                      [c[2] for c in combos], [c[3] for c in combos],
                      [c[0][1] for c in combos])

    def validate(self) -> "MCAxes":
        """Raise `SweepValidationError` before any compile time is spent
        (see `sweep.SweepAxes.validate`)."""
        if len(self) == 0:
            raise SweepValidationError(
                "designs", "empty MC sweep: zero configurations")
        seen: set = set()
        for d in self.designs:
            if id(d) not in seen:
                seen.add(id(d))
                d.validate()
        for i, kw in enumerate(self.sku_kw):
            if kw is not None and kw <= 0:
                raise SweepValidationError(
                    "sku_kw", f"sku_kw[{i}] = {kw}: non-positive rack "
                    f"power override")
        for i, p in enumerate(self.policies):
            if not 0 <= p < len(POLICY_NAMES):
                raise SweepValidationError(
                    "policies", f"policies[{i}] = {p} outside "
                    f"[0, {len(POLICY_NAMES)}); have {POLICY_NAMES}")
        return self


@dataclass
class MCResult:
    """Per-configuration MC metrics, leading axes = (config, trial)."""
    axes: MCAxes
    lineup_stranding: np.ndarray   # [B, T, X_pad] (use result(i) to strip)
    hall_stranding: np.ndarray     # [B, T]
    deployed_kw: np.ndarray        # [B, T]
    saturated: np.ndarray          # [B, T] refill phase ended saturated
    placed_a: np.ndarray           # [B, T, E]
    placed_b: np.ndarray           # [B, T, E_b]
    ha_capacity_kw: np.ndarray     # [B]
    # --- metric stage (per-trial $/performance; see `sweep.SweepResult`) ---
    provisioned_mw: np.ndarray = None   # [B] hall nameplate
    model_names: List[str] = field(default_factory=list)   # [Mdl]
    delivered_tps: np.ndarray = None         # [B, T, Mdl]
    tps_per_provisioned_w: np.ndarray = None  # [B, T, Mdl]
    dollars_per_tps: np.ndarray = None       # [B, T, Mdl]
    # --- resilient execution (repro.core.resilience) ---
    report: object = None          # RunReport when run via resilient_mc_sweep

    def __len__(self):
        return len(self.axes)

    @property
    def n_trials(self) -> int:
        return self.deployed_kw.shape[1]

    @property
    def tags(self) -> List[str]:
        return self.axes.tags

    def result(self, i: int) -> dict:
        """Configuration `i` as the `singlehall.monte_carlo` metrics dict
        (line-up padding stripped to the design's own line-up count)."""
        X = self.axes.designs[i].n_lineups
        return {
            "lineup_stranding": self.lineup_stranding[i, :, :X],  # [T, X]
            "hall_stranding": self.hall_stranding[i],             # [T]
            "deployed_kw": self.deployed_kw[i],                   # [T]
            "ha_capacity_kw": float(self.ha_capacity_kw[i]),
            "saturated": self.saturated[i],
            "placed_a": self.placed_a[i],
            "placed_b": self.placed_b[i],
        }


# Request-keyed staging cache: (design, padded shape) → (topo, jt).
# DesignSpec is a frozen dataclass, so it hashes by value; repeated
# `monte_carlo` calls (e.g. Fig. 6's per-kW loop before batching) build
# each topology exactly once, mirroring the benchmarks' `_FLEET_CACHE`.
# The empty initial state needs no staging — it is created inside the
# traced trial (`placement.init_state_from`), like the fleet scan does.
_TOPO_CACHE: Dict[tuple, Tuple[HallTopology, JaxTopology]] = {}


def _staged_topology(design: DesignSpec, rows_per_hall: int,
                     lineups_per_hall: int):
    key = (design, rows_per_hall, lineups_per_hall)
    if key not in _TOPO_CACHE:
        topo = build_topology(design, 1, rows_per_hall=rows_per_hall,
                              lineups_per_hall=lineups_per_hall)
        _TOPO_CACHE[key] = (topo, pl.jax_topology(topo))
    return _TOPO_CACHE[key]


def _mc_trial(jt_c, pol, t_a, t_b, k, *, harvest, with_pods, **statics):
    """One trial's device outputs.  The empty initial state is built
    inside the trace (`init_state_from`), so every operand carries the
    batch axes.  `statics` forwards the split-pods placement-mode
    keywords (`split_pods`, `pod_windows`, `cluster_starts`,
    `pod_scan_len`, `hd_scan`) to `run_trial`."""
    state, res_a, res_b = run_trial(jt_c, pl.init_state_from(jt_c),
                                    t_a, t_b, pol, k, harvest, with_pods,
                                    **statics)
    return (pl.lineup_stranding(jt_c, state),
            pl.hall_stranding(jt_c, state)[0],
            pl.deployed_kw(state),
            res_b.saturated, res_a.placed, res_b.placed)


_MC_STATICS = ("harvest", "with_pods", "split_pods", "pod_windows",
               "cluster_starts", "pod_scan_len", "hd_scan", "use_kernel",
               "kernel_interpret")


@functools.partial(jax.jit, static_argnames=_MC_STATICS)
def _mc_sweep_jit(jt, ta, tb, keys, policy, harvest, with_pods,
                  split_pods=False, pod_windows=(0, 0),
                  cluster_starts=(0, 0), pod_scan_len=pl.MAX_POD_RACKS,
                  hd_scan=None, use_kernel=False, kernel_interpret=False):
    """vmap `_mc_trial` over (configuration × trial): [B] topology /
    policy axes outer, [B, T] trace/key axes inner."""
    trial = functools.partial(
        _mc_trial, harvest=harvest, with_pods=with_pods,
        split_pods=split_pods, pod_windows=pod_windows,
        cluster_starts=cluster_starts, pod_scan_len=pod_scan_len,
        hd_scan=hd_scan, use_kernel=use_kernel,
        kernel_interpret=kernel_interpret)
    per_cfg = jax.vmap(trial, in_axes=(None, None, 0, 0, 0))
    return jax.vmap(per_cfg)(jt, policy, ta, tb, keys)


@functools.partial(jax.jit, static_argnames=_MC_STATICS + ("mesh",),
                   donate_argnums=tuple(range(5)))
def _mc_sharded_jit(jt, ta, tb, keys, policy, mesh, harvest, with_pods,
                    split_pods=False, pod_windows=(0, 0),
                    cluster_starts=(0, 0), pod_scan_len=pl.MAX_POD_RACKS,
                    hd_scan=None, use_kernel=False, kernel_interpret=False):
    """Sharded trial batch: operands arrive FLATTENED to one [B·T]
    (config × trial) axis — `sharded_mc_sweep` repeats the per-config
    topology/policy per trial — which a single `vmap` consumes under
    `shard_map`, so trials load-balance across devices in B·T/(dc·dt)
    slabs (`batch_spec` product-shards the flat axis over both mesh
    axes; a (D, 1) mesh is bitwise the historical 1-D layout).
    (A nested config × trial vmap inside `shard_map` trips an XLA CPU
    compile crash; the flat axis sidesteps it and shards finer anyway.)
    Trials are independent, so out_specs stay sharded; no collectives.
    Operand buffers are donated — the staged flat batch dies with the
    dispatch."""
    spec = shax.batch_spec()
    fn = jax.vmap(lambda jt_c, t_a, t_b, k, pol: _mc_trial(
        jt_c, pol, t_a, t_b, k, harvest=harvest, with_pods=with_pods,
        split_pods=split_pods, pod_windows=pod_windows,
        cluster_starts=cluster_starts, pod_scan_len=pod_scan_len,
        hd_scan=hd_scan, use_kernel=use_kernel,
        kernel_interpret=kernel_interpret))
    sharded = shax.shard_map(fn, mesh=mesh, in_specs=(spec,) * 5,
                             out_specs=spec, check_vma=False)
    return sharded(jt, ta, tb, keys, policy)


@functools.partial(jax.jit, static_argnames=_MC_STATICS + ("mesh",),
                   donate_argnums=tuple(range(5)))
def _mc_sharded2d_jit(jt, ta, tb, keys, policy, mesh, harvest, with_pods,
                      split_pods=False, pod_windows=(0, 0),
                      cluster_starts=(0, 0), pod_scan_len=pl.MAX_POD_RACKS,
                      hd_scan=None, use_kernel=False,
                      kernel_interpret=False):
    """2-D grid sharding: the [B, T] trial grid block-shards over the
    (config × trial) mesh — configurations over `CONFIG_AXIS`, trial
    replicas over `TRIAL_AXIS` — while the [B] topology/policy leaves
    shard over `CONFIG_AXIS` only (replicated across the trial axis).
    The global per-trial `jnp.repeat` of topologies the flat path stages
    on the host never happens: each shard repeats its own [b] slab
    across its [t] local trials *inside* the compiled program, flattens
    to one [b·t] axis for a single vmap (the nested-vmap XLA CPU crash
    again), and reshapes back, so out_specs are grid-sharded [B, T]."""
    cspec = shax.config_spec()
    gspec = shax.grid_spec()
    trial = functools.partial(
        _mc_trial, harvest=harvest, with_pods=with_pods,
        split_pods=split_pods, pod_windows=pod_windows,
        cluster_starts=cluster_starts, pod_scan_len=pod_scan_len,
        hd_scan=hd_scan, use_kernel=use_kernel,
        kernel_interpret=kernel_interpret)
    fn = jax.vmap(lambda jt_c, t_a, t_b, k, pol: trial(jt_c, pol, t_a,
                                                       t_b, k))

    def shard_fn(jt_s, ta_s, tb_s, keys_s, pol_s):
        b, t = keys_s.shape[:2]
        # tile [b, …] → [b·t, …] with a GATHER, not broadcast/repeat:
        # broadcasting a config-sharded, trial-replicated operand inside
        # the shard SIGFPEs the XLA CPU partitioner (same family as the
        # nested-vmap crash); the row gather compiles clean everywhere
        rep = jnp.arange(b * t) // t
        jt_f = jax.tree.map(lambda x: x[rep], jt_s)
        pol_f = pol_s[rep]
        ta_f, tb_f, keys_f = jax.tree.map(
            lambda x: x.reshape((b * t,) + x.shape[2:]),
            (ta_s, tb_s, keys_s))
        out = fn(jt_f, ta_f, tb_f, keys_f, pol_f)
        return jax.tree.map(
            lambda x: x.reshape((b, t) + x.shape[1:]), out)

    sharded = shax.shard_map(shard_fn, mesh=mesh,
                             in_specs=(cspec, gspec, gspec, gspec, cspec),
                             out_specs=gspec, check_vma=False)
    return sharded(jt, ta, tb, keys, policy)


def _pod_geometry(batches) -> Tuple[int, int]:
    """(max, min) per-trial pod count over a list of `TraceBatch`es — the
    static pod-window length and cluster-window start the split-pods
    scan compiles.  Also validates the pods-first contract (mirroring
    `fleet._event_windows`)."""
    counts = np.concatenate([b.n_pods.ravel() for b in batches])
    for b in batches:
        ip = np.asarray(b.is_pod)
        if np.any(ip[:, 1:] & ~ip[:, :-1]):
            raise ValueError(
                "split-pods scan needs pod events to precede cluster "
                "events within each trial (the generated-trace order); "
                "use legacy_pod_cond=True for unordered traces")
    return int(counts.max()), int(counts.min())


def _mc_prepare(axes: MCAxes, n_trials: int, n_events: int, year: int,
                scenario: str, gpu_power_share: float, pod_racks: int,
                quantum_racks: int, la_fraction: float,
                single_sku_gpu: bool, refill_events: int | None,
                legacy_pod_cond: bool = False):
    """Host-side staging shared by `mc_sweep` and `sharded_mc_sweep`:
    padded/stacked topologies ([B] leading axis), batched fill + refill
    trial traces ([B, T, E]), per-trial PRNG keys, per-config policies,
    plus the static placement-mode keywords for the jitted trial
    (`with_pods` / `split_pods` windows / `pod_scan_len` / `hd_scan`).

    Refill traces draw from the phase-1 stream of the *same* seed
    (`sample_mixed_traces(phase=1)`); the historical `seed + 1` refill
    made a configuration seeded `s` share its refill trace bitwise with
    configuration `s+1`'s fill trace — correlated trials across
    adjacent-seed grid points."""
    axes.validate()          # precise SweepValidationErrors, pre-compile
    B = len(axes)
    R_pad = max(d.n_rows for d in axes.designs)
    X_pad = max(d.n_lineups for d in axes.designs)
    staged = [_staged_topology(d, R_pad, X_pad) for d in axes.designs]
    jt = jax.tree.map(lambda *xs: jnp.stack(xs), *[s[1] for s in staged])

    E_b = refill_events or max(200, n_events // 3)
    share = 1.0 if single_sku_gpu else gpu_power_share
    gen = functools.partial(
        arrivals.sample_mixed_traces, year=year, scenario=scenario,
        gpu_power_share=share, pod_racks=pod_racks,
        quantum_racks=quantum_racks, la_fraction=la_fraction,
        single_sku_gpu=single_sku_gpu)
    stack = lambda ts: jax.tree.map(       # [B, T, E] device columns
        lambda *xs: jnp.stack(xs), *[TraceArrays.from_trace(t) for t in ts])
    tas = [gen(n_trials, n_events, seed=s, sku_kw_override=kw)
           for s, kw in zip(axes.seeds, axes.sku_kw)]
    tbs = [gen(n_trials, E_b, seed=s, phase=1, sku_kw_override=kw)
           for s, kw in zip(axes.seeds, axes.sku_kw)]
    with_pods = any(bool(t.is_pod.any()) for t in tas + tbs)
    statics = dict(with_pods=with_pods)
    if with_pods and not legacy_pod_cond:
        # windows bucket to 4 (pod window up, cluster start down) so
        # same-shape grids over fresh seeds reuse the compiled executable
        # despite per-seed pod-count jitter; the cost is at most 3 dead
        # scan steps per window
        wa, sa = _pod_geometry(tas)
        wb, sb = _pod_geometry(tbs)
        bucket = lambda n, E: min(-(-n // 4) * 4, E)
        statics.update(
            split_pods=True,
            pod_windows=(bucket(wa, n_events), bucket(wb, E_b)),
            cluster_starts=(sa // 4 * 4, sb // 4 * 4),
            pod_scan_len=min(max(t.max_pod_racks for t in tas + tbs),
                             pl.MAX_POD_RACKS),
            hd_scan=max(s[0].n_hd_rows for s in staged))
    ta, tb = stack(tas), stack(tbs)
    keys = jnp.stack([jax.random.split(jax.random.PRNGKey(s), n_trials)
                      for s in axes.seeds])
    policy = jnp.asarray(axes.policies, jnp.int32)
    return (jt, ta, tb, keys, policy), statics


def _mc_finalize(out, axes: MCAxes, models=None, year: int = 2028,
                 scenario: str = proj.MED, gpu_share: float = 1.0,
                 pod_racks: int = 1) -> MCResult:
    lineup_str, hall_str, deployed, saturated, placed_a, placed_b = out
    deployed = np.asarray(deployed)                              # [B, T] kW
    provisioned = np.array([d.ha_capacity_kw / 1e3 for d in axes.designs])
    models = (tp.MODEL_SUITE if models is None
              else tuple(tp.resolve_model(m) for m in models))
    if models:
        # one serving deployment for the whole call (year/scenario/pod size
        # are call-level), so the metric stage is a single [1, Mdl] grid
        dep = tp.serving_deployment(year, scenario, pod_racks)
        tpw = np.asarray(tp.tps_per_watt_grid(models, [dep]))[0]  # [Mdl]
        capex = np.array([cost.hall_capex(d) for d in axes.designs])
        delivered = (deployed * 1e3 * gpu_share)[..., None] * tpw
        with np.errstate(divide="ignore", invalid="ignore"):
            tps_per_pw = delivered / (provisioned[:, None, None] * 1e6)
            dpt = np.where(delivered > 0,
                           capex[:, None, None] / delivered, np.nan)
    else:
        B, T = deployed.shape
        delivered = np.zeros((B, T, 0))
        tps_per_pw, dpt = delivered.copy(), delivered.copy()
    return MCResult(
        axes=axes,
        lineup_stranding=np.asarray(lineup_str),
        hall_stranding=np.asarray(hall_str),
        deployed_kw=deployed,
        saturated=np.asarray(saturated),
        placed_a=np.asarray(placed_a),
        placed_b=np.asarray(placed_b),
        ha_capacity_kw=np.array([d.ha_capacity_kw for d in axes.designs]),
        provisioned_mw=provisioned,
        model_names=[m.name for m in models],
        delivered_tps=delivered,
        tps_per_provisioned_w=tps_per_pw,
        dollars_per_tps=dpt,
    )


def mc_sweep(axes: MCAxes, n_trials: int = 32, n_events: int = 600,
             year: int = 2028, scenario: str = proj.MED,
             gpu_power_share: float = 0.6, pod_racks: int = 1,
             quantum_racks: int = 10, la_fraction: float = 0.0,
             harvest: bool = True, single_sku_gpu: bool = False,
             refill_events: int | None = None,
             legacy_pod_cond: bool = False, models=None,
             use_kernel: bool | None = None,
             kernel_interpret: bool = False) -> MCResult:
    """Evaluate every single-hall MC configuration in `axes` in one
    compiled call (`n_trials` trials each).

    Trial traces come from `arrivals.sample_mixed_traces` — one
    vectorized numpy pass per configuration phase, seeded by the
    configuration's `seed` at phase 0 (fill) and phase 1 (refill) — and
    `singlehall.run_trial` is vmapped over the (config × trial) grid.
    Topologies are padded to the batch's common (rows, line-ups) shape;
    padding rows have zero capacity and padded line-ups are inactive, so
    real-row results are unchanged and `result(i)` strips the padding.

    Pod traces (`pod_racks > 1`) compile the split-pods fast path: the
    generator emits pods first within every trial, so each phase runs a
    pod window (`placement._place_pod` over the HD-compacted row view,
    rack scan trimmed to the batch's true max pod size) then a cluster
    window (`place_cluster_in_row`), instead of paying `place`'s
    `lax.cond(is_pod, …)` both-branches cost on every event under vmap.
    Results are bit-identical to `legacy_pod_cond=True`, which keeps the
    per-event cond path compilable as the regression/benchmark
    reference (`benchmarks/run.py --only mc_pod_speedup`).

    Args:
        axes: the configuration batch (see `MCAxes`).
        n_trials / n_events: trials per configuration, fill-phase events.
        year / scenario: SKU-projection operating point (all configs).
        gpu_power_share / pod_racks / quantum_racks / la_fraction: trace
            mix parameters (`arrivals.sample_mixed_traces`).
        harvest: apply the §5.2 harvest between fill and refill (static).
        single_sku_gpu: Fig. 6 mode — GPU-only events at each
            configuration's `sku_kw` override.
        refill_events: refill-phase event count (default
            ``max(200, n_events // 3)``, matching `monte_carlo`).
        legacy_pod_cond: compile the pre-split per-event
            `lax.cond(is_pod, …)` path instead (results identical).
        models: Table 2 models (objects or names) for the per-trial
            $/performance columns (default `throughput.MODEL_SUITE`;
            `()` skips the stage).
        use_kernel: route placement scoring through the fused Pallas
            kernel (static; bitwise-identical results).  `None` = backend
            default: on for TPU, off elsewhere
            (`placement.default_use_kernel`).
        kernel_interpret: run the kernel in Pallas interpret mode (the
            CPU CI fallback; only meaningful with `use_kernel=True`).
    """
    args, statics = _mc_prepare(axes, n_trials, n_events, year, scenario,
                                gpu_power_share, pod_racks,
                                quantum_racks, la_fraction,
                                single_sku_gpu, refill_events,
                                legacy_pod_cond)
    out = _mc_sweep_jit(*args, harvest=harvest,
                        use_kernel=pl.resolve_use_kernel(use_kernel),
                        kernel_interpret=kernel_interpret, **statics)
    return _mc_finalize(out, axes, models=models, year=year,
                        scenario=scenario,
                        gpu_share=1.0 if single_sku_gpu else gpu_power_share,
                        pod_racks=pod_racks)


def sharded_mc_sweep(axes: MCAxes, n_trials: int = 32, n_events: int = 600,
                     year: int = 2028, scenario: str = proj.MED,
                     gpu_power_share: float = 0.6, pod_racks: int = 1,
                     quantum_racks: int = 10, la_fraction: float = 0.0,
                     harvest: bool = True, single_sku_gpu: bool = False,
                     refill_events: int | None = None,
                     legacy_pod_cond: bool = False,
                     devices: Sequence[jax.Device] | None = None,
                     models=None, use_kernel: bool | None = None,
                     kernel_interpret: bool = False,
                     mesh_shape: Tuple[int, int] | None = None) -> MCResult:
    """`mc_sweep`, with the (config × trial) grid sharded over devices.

    Two placements on the named 2-D (config × trial) mesh
    (`repro.sharding.axes.sweep_mesh`):

    * Default (`mesh_shape=None` or a trial extent of 1): the FLATTENED
      `B·T` trial grid product-shards over both mesh axes — each trial
      is an independent simulation, so sharding trials, not just
      configurations, load-balances even when `B < D·T`.  Per-config
      topologies and policies are repeated per trial on the host, the
      flat batch splits over `devices` (default: all local devices) via
      `shard_map`, and outputs reshape back to `[B, T, …]`.  A (D, 1)
      mesh is bitwise the historical 1-D `CONFIG_AXIS` layout.
    * `mesh_shape=(dc, dt)` with `dt > 1`: the `[B, T]` grid
      block-shards — configurations over `CONFIG_AXIS`, trial replicas
      over `TRIAL_AXIS` — and topologies ship once per configuration
      ([B] leaves shard over `CONFIG_AXIS` only), never host-repeated
      per trial; each shard flattens its own (b × t) block inside the
      compiled program (`_mc_sharded2d_jit`).

    Non-divisible grids pad by replicating the first flat entry (or the
    first configuration/trial row on the 2-D path) and drop the
    replicas on exit; one device (or a single trial) is a passthrough
    to `mc_sweep`.  Simulated multi-device CPU runs use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """
    kw = dict(n_trials=n_trials, n_events=n_events, year=year,
              scenario=scenario, gpu_power_share=gpu_power_share,
              pod_racks=pod_racks, quantum_racks=quantum_racks,
              la_fraction=la_fraction, harvest=harvest,
              single_sku_gpu=single_sku_gpu, refill_events=refill_events,
              legacy_pod_cond=legacy_pod_cond, models=models,
              use_kernel=use_kernel, kernel_interpret=kernel_interpret)
    devs = list(devices) if devices is not None else list(jax.devices())
    B, T = len(axes), int(n_trials)
    if len(devs) <= 1 or B * T == 1:
        return mc_sweep(axes, **kw)

    (jt, ta, tb, keys, policy), statics = _mc_prepare(
        axes, n_trials, n_events, year, scenario, gpu_power_share,
        pod_racks, quantum_racks, la_fraction, single_sku_gpu,
        refill_events, legacy_pod_cond)
    mesh = shax.sweep_mesh(devs, mesh_shape)
    dc, dt = mesh.devices.shape

    if dt > 1:
        # ---- 2-D grid path: pad B → ·dc and T → ·dt, ship [B] leaves
        # config-sharded and [B, T] leaves grid-sharded ----
        B_pad, T_pad = -(-B // dc) * dc, -(-T // dt) * dt

        def pad_axis(x, n, axis):
            if x.shape[axis] == n:
                return x
            take = jnp.take(x, jnp.zeros((n - x.shape[axis],), jnp.int32),
                            axis=axis)
            return jnp.concatenate([x, take], axis=axis)

        cfg_leaves = jax.tree.map(lambda x: pad_axis(x, B_pad, 0),
                                  (jt, policy))
        grid_leaves = jax.tree.map(
            lambda x: pad_axis(pad_axis(x, B_pad, 0), T_pad, 1),
            (ta, tb, keys))
        cfg_leaves = jax.device_put(
            cfg_leaves, NamedSharding(mesh, shax.config_spec()))
        ta, tb, keys = jax.device_put(
            grid_leaves, NamedSharding(mesh, shax.grid_spec()))
        out = _mc_sharded2d_jit(cfg_leaves[0], ta, tb, keys, cfg_leaves[1],
                                harvest=harvest, mesh=mesh,
                                use_kernel=pl.resolve_use_kernel(use_kernel),
                                kernel_interpret=kernel_interpret, **statics)
        out = jax.tree.map(lambda x: x[:B, :T], out)
    else:
        # ---- flat path: repeat per-config leaves per trial and shard
        # the [B·T] axis over the whole mesh ----
        jt = jax.tree.map(lambda x: jnp.repeat(x, T, axis=0), jt)
        policy = jnp.repeat(policy, T)
        flat = jax.tree.map(lambda x: x.reshape((B * T,) + x.shape[2:]),
                            (ta, tb, keys))
        args = (jt,) + flat + (policy,)

        D = len(devs)
        N_pad = -(-B * T // D) * D
        if N_pad != B * T:
            def pad(x):
                fill = jnp.broadcast_to(x[:1],
                                        (N_pad - B * T,) + x.shape[1:])
                return jnp.concatenate([x, fill])
            args = jax.tree.map(pad, args)

        args = jax.device_put(args, NamedSharding(mesh, shax.batch_spec()))
        out = _mc_sharded_jit(*args, harvest=harvest, mesh=mesh,
                              use_kernel=pl.resolve_use_kernel(use_kernel),
                              kernel_interpret=kernel_interpret, **statics)
        out = jax.tree.map(
            lambda x: x[:B * T].reshape((B, T) + x.shape[1:]), out)
    return _mc_finalize(out, axes, models=models, year=year,
                        scenario=scenario,
                        gpu_share=1.0 if single_sku_gpu else gpu_power_share,
                        pod_racks=pod_racks)
