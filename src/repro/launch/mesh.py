"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state.  The dry-run forces 512 host
devices via XLA_FLAGS *before* any JAX import (see `dryrun.py`).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(jax.devices())} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (dryrun.py does this)")
    return jax.make_mesh(shape, axes, devices=devices)


def make_test_mesh(shape=(2, 2, 2), axes=("pod", "data", "model")):
    """Small mesh for unit tests (8 host devices)."""
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])
