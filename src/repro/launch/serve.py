"""Serving launcher: batched continuous-batching engine over a model.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-moe-1b-a400m \
        --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_config, get_smoke_config
from ..models.api import build_model
from ..serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the synthetic request stream (the "
                         "default reproduces the historical rng(0) "
                         "stream)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, batch_slots=args.slots,
                         max_seq=args.max_seq, prompt_len=args.prompt_len)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        engine.submit(Request(
            rid, rng.integers(0, cfg.vocab, size=args.prompt_len),
            max_new_tokens=args.max_new))
    t0 = time.time()
    steps = engine.run_until_drained()
    dt = time.time() - t0
    print(f"arch={cfg.name} requests={args.requests} slots={args.slots} "
          f"engine_steps={steps} prefills={engine.stats['prefills']} "
          f"decode_steps={engine.stats['decode_steps']} "
          f"tokens={engine.stats['tokens']} tok/s={engine.stats['tokens']/dt:,.0f}")
    return engine.stats


if __name__ == "__main__":
    main()
