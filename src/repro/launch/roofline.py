"""Roofline report: per (arch × shape × mesh) three-term analysis from the
dry-run artifacts.

    compute   = HLO_FLOPs / (chips · 197 TFLOP/s)        [per-device HLO]
    memory    = HLO_bytes / (chips · 819 GB/s)
    collective= collective_bytes / (chips · 50 GB/s/link)

(HLO quantities are per-device — SPMD shapes are already partitioned — so
the chips factor is implicit.)  Also reports MODEL_FLOPS = 6·N_active·D
(train) / 2·N_active·D (serve), the useful-FLOPs fraction, the dominant
term, and the roofline fraction = t_compute / max(terms).

    PYTHONPATH=src python -m repro.launch.roofline [--mesh 16x16] [--csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if "error" not in r:
            recs.append(r)
    return recs


def fmt_row(r: Dict) -> Dict:
    tc, tm, tl = r["t_compute"], r["t_memory"], r["t_collective"]
    dom = max(tc, tm, tl)
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "t_compute_s": tc, "t_memory_s": tm, "t_collective_s": tl,
        "bottleneck": r["bottleneck"],
        "roofline_fraction": tc / dom if dom else 0.0,
        "useful_flops_frac": r.get("useful_flops_fraction", 0.0),
        "model_flops": r.get("model_flops_global", 0.0),
        "hbm_gb_per_dev": (r.get("argument_size_in_bytes", 0)
                           + r.get("temp_size_in_bytes", 0)) / 1e9,
        "fits_v5e_16g": (r.get("argument_size_in_bytes", 0)
                         + r.get("temp_size_in_bytes", 0)) < 16e9,
        "compile_s": r.get("compile_seconds", 0.0),
    }


def one_liner(r: Dict) -> str:
    """What would move the dominant term down (heuristic advisor)."""
    f = fmt_row(r)
    b = f["bottleneck"]
    if b == "collective":
        if r["shape"] == "train_4k":
            return ("shrink TP width / move act gathers to bf16 / "
                    "reduce-scatter instead of all-reduce")
        return "keep EP traffic pod-local; batch KV collectives"
    if b == "memory":
        if r["step"] == "decode":
            return "decode is weight/KV-bandwidth bound (expected); raise batch"
        return "blockwise attention + fewer f32 materializations"
    return "compute-bound: raise per-chip utilization (good place to be)"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None, choices=[None, "16x16", "2x16x16"])
    ap.add_argument("--csv", action="store_true")
    ap.add_argument("--dir", default=DRYRUN_DIR)
    args = ap.parse_args(argv)

    recs = load_records(args.dir)
    if args.mesh:
        recs = [r for r in recs if r["mesh"] == args.mesh]
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    if args.csv:
        cols = ["arch", "shape", "mesh", "t_compute_s", "t_memory_s",
                "t_collective_s", "bottleneck", "roofline_fraction",
                "useful_flops_frac", "hbm_gb_per_dev", "fits_v5e_16g"]
        print(",".join(cols))
        for r in recs:
            f = fmt_row(r)
            print(",".join(
                f"{f[c]:.4g}" if isinstance(f[c], float) else str(f[c])
                for c in cols))
        return

    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':8s} {'t_comp':>9s} "
           f"{'t_mem':>9s} {'t_coll':>9s} {'bound':>10s} {'roofl%':>7s} "
           f"{'useful%':>8s} {'GB/dev':>7s} fit")
    print(hdr)
    print("-" * len(hdr))
    for r in recs:
        f = fmt_row(r)
        print(f"{f['arch']:26s} {f['shape']:12s} {f['mesh']:8s} "
              f"{f['t_compute_s']:9.3g} {f['t_memory_s']:9.3g} "
              f"{f['t_collective_s']:9.3g} {f['bottleneck']:>10s} "
              f"{100*f['roofline_fraction']:6.1f}% "
              f"{100*f['useful_flops_frac']:7.1f}% "
              f"{f['hbm_gb_per_dev']:7.2f} "
              f"{'Y' if f['fits_v5e_16g'] else 'N'}")
    print()
    for r in recs:
        f = fmt_row(r)
        if f["roofline_fraction"] < 0.25 or not f["fits_v5e_16g"]:
            print(f"* {f['arch']} {f['shape']} {f['mesh']}: {one_liner(r)}")


if __name__ == "__main__":
    main()
