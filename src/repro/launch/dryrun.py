import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the production mesh and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: experiments/dryrun/<arch>__<shape>__<mesh>.json (schema in
`repro.core.calibration`).  The 512 placeholder host devices exist ONLY in
this process; smoke tests and benchmarks see 1 device.
"""

import argparse
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import ARCH_IDS, get_config
from ..models.api import build_model
from ..optim import adamw
from ..sharding import axes as ax
from ..train.step import make_train_step
from . import shapes as sh
from .hlo_analysis import analyze, extract_cost, extract_memory
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# TPU v5e hardware constants (roofline targets).
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


# §Perf winning variants (hypothesis→measure log in EXPERIMENTS.md).
# --variant optimized applies these; baseline ignores them.
PERF_VARIANTS = {
    # small dense/SSM models at global batch 256: pure 256/512-way DP;
    # fsdp=True adds ZeRO-3 param sharding over `data` where replicated
    # params + f32 grads would exceed the 16 GB/chip budget
    ("qwen3-1.7b", "train_4k"): ("pure_dp", {"fsdp": True}),
    ("mamba2-2.7b", "train_4k"): ("pure_dp", {"ssm_chunk": 64,
                                              "fsdp": True}),
    ("qwen2-vl-2b", "train_4k"): ("pure_dp", {"fsdp": True}),
    # MoE dispatch groups interact badly with pod-axis context parallelism
    # (measured 31 s collective; EXPERIMENTS §Perf) — single-pod only.
    ("granite-moe-1b-a400m", "train_4k"): ("pure_dp_singlepod", {}),
    ("whisper-small", "train_4k"): ("pure_dp", {}),
    ("phi4-mini-3.8b", "train_4k"): ("pure_dp", {"fsdp": True}),
}


def rules_for(shape_name: str, multi_pod: bool, overrides=None) -> ax.Rules:
    if shape_name == "long_500k":
        r = ax.sequence_parallel_rules(multi_pod)
    elif shape_name == "decode_32k":
        # flash-decode: KV cache sequence-sharded over `model` (the KV-head
        # counts of the assigned archs don't divide 16; the sequence always
        # does), partial softmax combined by an all-reduce.
        r = ax.base_rules(multi_pod)
        r["seq_kv"] = "model"
        r["kv_heads"] = None
    else:
        r = ax.base_rules(multi_pod)
    if overrides:
        r.update(overrides)
    return r


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               rule_overrides=None, cfg_overrides=None,
               variant: str = "baseline"):
    """Build + lower + compile one cell.  Returns (record, compiled)."""
    import dataclasses
    cfg = get_config(arch_id)
    if variant == "optimized" and (arch_id, shape_name) in PERF_VARIANTS:
        kind, cfg_ovr = PERF_VARIANTS[(arch_id, shape_name)]
        if kind == "pure_dp_singlepod" and multi_pod:
            raise ValueError(
                f"{arch_id} {shape_name}: optimized variant is single-pod "
                "only (use baseline for 2x16x16)")
        cfg_overrides = {**cfg_ovr, **(cfg_overrides or {})}
        if kind.startswith("pure_dp"):
            rule_overrides = {**ax.pure_dp_rules(multi_pod),
                              **(rule_overrides or {})}
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    model = build_model(cfg)
    sp = sh.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(shape_name, multi_pod, rule_overrides)
    n_dev = mesh.devices.size
    variant_tag = variant

    with ax.use_rules(rules, mesh):
        p_axes = model.param_axes()
        params_abs = model.abstract_params()
        param_rules = ax.fsdp_rules(rules, multi_pod) if cfg.fsdp else rules
        p_shard = ax.tree_shardings_matched(p_axes, params_abs, mesh,
                                            param_rules)
        batch_rules = rules

        def batch_shardings(specs):
            return {
                k: jax.sharding.NamedSharding(mesh, ax.divisible_spec(
                    ax.spec_for(("batch", "seq") if v.ndim == 2 else
                                ("batch", "seq", None), batch_rules),
                    v.shape, mesh))
                for k, v in specs.items()}

        if sp.step == "train":
            opt_rules = ax.opt_rules(param_rules, multi_pod)
            opt_abs = jax.eval_shape(adamw.init, params_abs)
            mu_shard = ax.tree_shardings_matched(p_axes, opt_abs.mu, mesh,
                                                 opt_rules)
            opt_shard = adamw.AdamWState(
                jax.sharding.NamedSharding(mesh, ax.spec_for(())),
                mu_shard, jax.tree.map(lambda s: s, mu_shard))
            batch_abs = sh.train_batch_specs(cfg, sp)
            b_shard = batch_shardings(batch_abs)
            step_fn = make_train_step(model, adamw.AdamWConfig())
            jitted = jax.jit(step_fn,
                             in_shardings=(p_shard, opt_shard, b_shard),
                             out_shardings=(p_shard, opt_shard, None),
                             donate_argnums=(0, 1))
            with mesh:
                lowered = jitted.lower(params_abs, opt_abs, batch_abs)

        elif sp.step == "prefill":
            batch_abs = sh.prefill_batch_specs(cfg, sp)
            b_shard = batch_shardings(batch_abs)
            caches_abs = jax.eval_shape(
                lambda: model.init_caches(sp.batch, sp.seq))
            cache_shard = ax.tree_shardings_matched(
                model.cache_axes(), caches_abs, mesh, rules)

            def prefill_step(params, batch):
                return model.prefill(params, batch, sp.seq)

            jitted = jax.jit(prefill_step,
                             in_shardings=(p_shard, b_shard),
                             out_shardings=(None, cache_shard))
            with mesh:
                lowered = jitted.lower(params_abs, batch_abs)

        else:  # decode
            token_abs, pos_abs = sh.decode_token_specs(cfg, sp)
            caches_abs = jax.eval_shape(
                lambda: model.init_caches(sp.batch, sp.seq))
            cache_shard = ax.tree_shardings_matched(
                model.cache_axes(), caches_abs, mesh, rules)
            tok_shard = jax.sharding.NamedSharding(mesh, ax.divisible_spec(
                ax.spec_for(("batch", None), rules), (sp.batch, 1), mesh))
            pos_shard = jax.sharding.NamedSharding(mesh, ax.spec_for((), rules))

            def decode(params, token, pos, caches):
                return model.decode_step(params, token, pos, caches)

            jitted = jax.jit(decode,
                             in_shardings=(p_shard, tok_shard, pos_shard,
                                           cache_shard),
                             out_shardings=(None, cache_shard),
                             donate_argnums=(3,))
            with mesh:
                lowered = jitted.lower(params_abs, token_abs,
                                       jnp.zeros((), jnp.int32), caches_abs)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    rec = {
        "arch": arch_id, "shape": shape_name, "variant": variant_tag,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev, "step": sp.step,
        "batch": sp.batch, "seq": sp.seq,
        "compile_seconds": compile_s,
        "n_params": model.n_params(),
    }
    rec.update(extract_memory(compiled))
    rec.update(extract_cost(compiled))
    hc = analyze(compiled.as_text(), n_dev)
    rec.update({f"bytes_{k}": v for k, v in hc.collective_bytes.items()})
    rec.update({f"count_{k}": v for k, v in hc.collective_counts.items()})
    rec["n_while"] = hc.n_while

    # roofline terms (per-device, per-step) — loop-aware HLO accounting
    flops = hc.flops
    bytes_ = hc.hbm_bytes
    rec["flops_per_device"] = flops
    rec["bytes_per_device"] = bytes_
    rec["collective_bytes_per_device"] = hc.collective_total
    rec["t_compute"] = flops / PEAK_FLOPS
    rec["t_memory"] = bytes_ / HBM_BW
    rec["t_collective"] = hc.collective_total / ICI_BW
    terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
             "collective": rec["t_collective"]}
    rec["bottleneck"] = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D per step (train: ×3 fwd+bwd
    # is already the 6 factor; serve: 2·N·D)
    n_active = cfg.active_params_estimate()
    tokens = sp.batch * (sp.seq if sp.step != "decode" else 1)
    model_flops = (6 if sp.step == "train" else 2) * n_active * tokens
    rec["model_flops_global"] = float(model_flops)
    hlo_global = flops * n_dev
    rec["useful_flops_fraction"] = (
        float(model_flops) / hlo_global if hlo_global else 0.0)
    return rec, compiled


def run_cell(arch_id, shape_name, multi_pod, out_dir=OUT_DIR, verbose=True,
             variant="baseline"):
    os.makedirs(out_dir, exist_ok=True)
    hlo_dir = os.path.join(out_dir, "..", "hlo")
    os.makedirs(hlo_dir, exist_ok=True)
    tag = f"{arch_id}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    if variant != "baseline":
        tag += "__opt"
    path = os.path.join(out_dir, tag + ".json")
    try:
        rec, compiled = lower_cell(arch_id, shape_name, multi_pod,
                                   variant=variant)
        with gzip.open(os.path.join(hlo_dir, tag + ".hlo.gz"), "wt") as f:
            f.write(compiled.as_text())
        if verbose:
            print(f"[OK] {tag}: compile={rec['compile_seconds']:.1f}s "
                  f"flops/dev={rec['flops_per_device']:.3e} "
                  f"coll/dev={rec['collective_bytes_per_device']:.3e} "
                  f"bottleneck={rec['bottleneck']}", flush=True)
        mem = rec.get("temp_size_in_bytes")
        if verbose and mem is not None:
            print(f"     mem: args={rec.get('argument_size_in_bytes', 0)/1e9:.2f}GB "
                  f"temp={mem/1e9:.2f}GB", flush=True)
    except Exception as e:
        rec = {"arch": arch_id, "shape": shape_name, "variant": variant,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "error": repr(e), "traceback": traceback.format_exc()}
        print(f"[FAIL] {tag}: {e!r}", flush=True)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def reanalyze_all(out_dir=OUT_DIR):
    """Recompute analyzer-derived metrics from the stored compiled HLO —
    analysis iterations without recompiling."""
    hlo_dir = os.path.join(out_dir, "..", "hlo")
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        path = os.path.join(out_dir, fn)
        with open(path) as f:
            rec = json.load(f)
        if "error" in rec:
            continue
        hlo_path = os.path.join(hlo_dir, fn[:-5] + ".hlo.gz")
        if not os.path.exists(hlo_path):
            continue
        with gzip.open(hlo_path, "rt") as f:
            txt = f.read()
        hc = analyze(txt, rec["n_devices"])
        rec.update({f"bytes_{k}": v for k, v in hc.collective_bytes.items()})
        rec.update({f"count_{k}": v for k, v in hc.collective_counts.items()})
        rec["n_while"] = hc.n_while
        rec["flops_per_device"] = hc.flops
        rec["bytes_per_device"] = hc.hbm_bytes
        rec["collective_bytes_per_device"] = hc.collective_total
        rec["t_compute"] = hc.flops / PEAK_FLOPS
        rec["t_memory"] = hc.hbm_bytes / HBM_BW
        rec["t_collective"] = hc.collective_total / ICI_BW
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        hlo_global = hc.flops * rec["n_devices"]
        rec["useful_flops_fraction"] = (
            rec["model_flops_global"] / hlo_global if hlo_global else 0.0)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[reanalyzed] {fn[:-5]}: bottleneck={rec['bottleneck']}",
              flush=True)


def all_cells():
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape_name in sh.applicable_cells(cfg):
            yield arch_id, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "optimized"])
    ap.add_argument("--reanalyze", action="store_true",
                    help="recompute roofline metrics from stored HLO "
                         "without recompiling")
    args = ap.parse_args()

    if args.reanalyze:
        reanalyze_all(args.out)
        return

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        cells = list(all_cells())
        if args.variant == "optimized":
            cells = [c for c in cells if c in PERF_VARIANTS]
    else:
        cfg = get_config(args.arch)
        shapes = [args.shape] if args.shape else sh.applicable_cells(cfg)
        cells = [(args.arch, s) for s in shapes]

    failures = 0
    for arch_id, shape_name in cells:
        for mp in meshes:
            if (args.variant == "optimized" and mp
                    and PERF_VARIANTS.get((arch_id, shape_name),
                                          ("", {}))[0].endswith("singlepod")):
                print(f"[skip] {arch_id} {shape_name} 2x16x16: optimized "
                      "variant is single-pod only", flush=True)
                continue
            tag = f"{arch_id}__{shape_name}__{'2x16x16' if mp else '16x16'}"
            if args.variant != "baseline":
                tag += "__opt"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if "error" not in json.load(f):
                        print(f"[skip] {tag}", flush=True)
                        continue
            rec = run_cell(arch_id, shape_name, mp, args.out,
                           variant=args.variant)
            failures += 1 if "error" in rec else 0
    print(f"done; failures={failures}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
