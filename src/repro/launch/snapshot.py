"""Append the final roofline results snapshot to EXPERIMENTS.md.

    PYTHONPATH=src python -m repro.launch.snapshot
"""
from __future__ import annotations

import json
import os

from .roofline import DRYRUN_DIR, fmt_row, load_records

EXP = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "EXPERIMENTS.md")
MARK = "## §Results snapshot"


def table(recs, title):
    lines = [f"### {title}", "",
             "| arch | shape | mesh | t_comp (s) | t_mem (s) | t_coll (s) |"
             " bound | roofline | useful | GB/dev | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        f = fmt_row(r)
        lines.append(
            f"| {f['arch']} | {f['shape']} | {f['mesh']} "
            f"| {f['t_compute_s']:.3g} | {f['t_memory_s']:.3g} "
            f"| {f['t_collective_s']:.3g} | {f['bottleneck']} "
            f"| {100*f['roofline_fraction']:.1f}% "
            f"| {100*f['useful_flops_frac']:.0f}% "
            f"| {f['hbm_gb_per_dev']:.1f} "
            f"| {'Y' if f['fits_v5e_16g'] else 'N'} |")
    return "\n".join(lines) + "\n"


def main():
    recs = load_records()
    base = [r for r in recs if r.get("variant", "baseline") == "baseline"]
    opt = [r for r in recs if r.get("variant") == "optimized"]
    base.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    opt.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    out = [MARK, "",
           f"{len(base)} baseline cells + {len(opt)} optimized variants; "
           "terms per §Roofline (per-device, per-step).", "",
           table(base, "Baseline (paper-faithful defaults)"), "",
           table(opt, "Optimized variants (--variant optimized; §Perf)")]

    with open(EXP) as f:
        text = f.read()
    head = text.split(MARK)[0]
    with open(EXP, "w") as f:
        f.write(head + "\n".join(out) + "\n")
    print(f"snapshot appended: {len(base)} baseline, {len(opt)} optimized")


if __name__ == "__main__":
    main()
