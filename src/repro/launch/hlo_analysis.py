"""Compiled-HLO analysis: loop-aware FLOPs, HBM-traffic and collective-byte
accounting.

Why not `compiled.cost_analysis()`: XLA's analysis counts each `while`
(lax.scan) body ONCE, so a scanned-layers model under-reports FLOPs,
bytes, and collectives by ~n_layers×.  This analyzer parses the compiled
HLO text, builds the computation call graph, extracts per-`while` trip
counts from the loop condition, and multiplies body costs accordingly
(nested loops compose).

Accounting:
* FLOPs: every `dot` — 2 · prod(result dims) · prod(lhs contracting dims).
* HBM bytes: per *top-level* instruction of structural computations
  (entry, while bodies/conds, called subcomputations): result bytes +
  array-operand bytes.  Instructions inside fusions are excluded (they
  live in registers/VMEM on the target), mirroring TPU cost semantics.
* Collective link bytes (per device, ring-model effective factors):
      all-gather      out·(n−1)/n        reduce-scatter  in·(n−1)/n
      all-reduce      2·in·(n−1)/n       all-to-all      in·(n−1)/n
      collective-permute  in
  with n = replica-group size.  Compiled SPMD shapes are per-device.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+)\s*:\s*(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_ATTR_COMP_RE = {
    "condition": re.compile(r"condition=%?([\w\.\-]+)"),
    "body": re.compile(r"body=%?([\w\.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w\.\-]+)"),
    "calls": re.compile(r"calls=%?([\w\.\-]+)"),
    "branches": re.compile(r"branch_computations=\{([^}]*)\}"),
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _dims(dims_str: str) -> int:
    n = 1
    for d in dims_str.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims_str: str) -> int:
    return _DTYPE_BYTES.get(dtype, 4) * _dims(dims_str)


@dataclass
class Instruction:
    name: str
    op: str
    line: str
    result_shapes: List[Tuple[str, str]]       # [(dtype, dims), ...]
    operands: List[str]


@dataclass
class Computation:
    name: str
    is_entry: bool
    instructions: List[Instruction] = field(default_factory=list)
    shapes: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)


_OPS_OF_INTEREST = re.compile(
    r"\b(dot|fusion|while|call|conditional|convolution|parameter|constant|"
    r"all-reduce-start|all-reduce|all-gather-start|all-gather|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute|"
    r"dynamic-update-slice|dynamic-slice|get-tuple-element|tuple|copy|"
    r"broadcast|iota|reduce-window|reduce|transpose|reshape|convert|"
    r"bitcast|compare|add|subtract|multiply|divide|custom-call|scatter|"
    r"gather|rng|select|exponential|log|tanh|sort)\b")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        header = re.match(
            r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->.*\{$", s)
        if header and not s.startswith("//"):
            cur = Computation(header.group(2), bool(header.group(1)))
            comps[cur.name] = cur
            for pname, pdtype, pdims in _PARAM_RE.findall(header.group(3)):
                cur.shapes[pname] = [(pdtype, pdims)]
            continue
        if s == "}" or cur is None:
            continue
        m = _DEF_RE.match(s)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # skip the result type (may itself be a parenthesized tuple)
        i = 0
        if rhs.startswith("("):
            depth = 0
            for j, ch in enumerate(rhs):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        i = j + 1
                        break
        tail = rhs[i:]
        opm = re.search(r"([\w\-]+)\(", tail)
        if not opm:
            continue
        op = opm.group(1)
        head = rhs[:i] if i else tail[:opm.start()]
        result_shapes = _SHAPE_RE.findall(rhs[:i + opm.start()])
        paren = i + opm.end() - 1
        args = rhs[paren + 1:]
        # cut at attribute section for operand extraction
        operands = _OPERAND_RE.findall(args.split("), ")[0])
        inst = Instruction(name, op, s, result_shapes, operands)
        cur.instructions.append(inst)
        cur.shapes[name] = result_shapes
    return comps


def _trip_count(cond: Computation) -> int:
    """Loop trip count: the constant operand of the condition's compare
    against the induction variable."""
    consts: Dict[str, int] = {}
    for inst in cond.instructions:
        if inst.op == "constant":
            m = re.search(r"constant\((\d+)\)", inst.line)
            if m:
                consts[inst.name] = int(m.group(1))
    for inst in cond.instructions:
        if inst.op == "compare":
            for o in inst.operands:
                if o in consts:
                    return consts[o]
    return max(consts.values()) if consts else 1


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


def _dot_flops(inst: Instruction, comp: Computation) -> float:
    res = sum(_dims(d) for _, d in inst.result_shapes) if inst.result_shapes else 0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
    if not m or not inst.operands:
        return 2.0 * res
    lhs = comp.shapes.get(inst.operands[0])
    if not lhs:
        return 2.0 * res
    lhs_dims = [int(x) for x in lhs[0][1].split(",") if x]
    contract = 1
    for i in (int(x) for x in m.group(1).split(",") if x):
        if i < len(lhs_dims):
            contract *= lhs_dims[i]
    return 2.0 * res * contract


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0

    @property
    def collective_total(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, n_devices: int) -> HloCost:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()
    cost = HloCost(collective_bytes={k: 0.0 for k in COLLECTIVE_OPS},
                   collective_counts={k: 0.0 for k in COLLECTIVE_OPS})
    comps_ref = (comps,)
    seen_stack = []

    def visit(comp: Computation, mult: float):
        if comp.name in seen_stack:       # recursion guard
            return
        seen_stack.append(comp.name)
        for inst in comp.instructions:
            op = inst.op
            if op == "dot":
                cost.flops += mult * _dot_flops(inst, comp)
            elif op == "convolution":
                res = sum(_dims(d) for _, d in inst.result_shapes)
                cost.flops += mult * 2.0 * res  # lower bound (no real convs)
            # HBM traffic model: every materialized top-level buffer is
            # written once and read ~once (2 × result bytes).  Operand
            # bytes are NOT summed — fusion operand lists include whole
            # stacked weight arrays whose dynamic-slices read only 1/L of
            # the buffer, which would overcount by ~n_layers.
            if op == "dynamic-update-slice":
                # writes only the update slice (result aliases the buffer)
                upd = comp.shapes.get(inst.operands[1]) if len(inst.operands) > 1 else None
                b = sum(_shape_bytes(dt, dm) for dt, dm in (upd or []))
                cost.hbm_bytes += mult * 2.0 * b
            elif op == "fusion":
                # a fusion whose root is a dynamic-update-slice writes only
                # the update slice (in-place buffer), not its full result —
                # scan-carried buffers otherwise overcount by trip_count×.
                b = None
                m = _ATTR_COMP_RE["calls"].search(inst.line)
                if m and m.group(1) in comps_ref[0]:
                    fc = comps_ref[0][m.group(1)]
                    dus = [fi for fi in fc.instructions
                           if fi.op == "dynamic-update-slice"]
                    if dus:
                        b = 0
                        for fi in dus:
                            upd = (fc.shapes.get(fi.operands[1])
                                   if len(fi.operands) > 1 else None)
                            b += sum(_shape_bytes(dt, dm)
                                     for dt, dm in (upd or []))
                if b is None:
                    b = sum(_shape_bytes(dt, dm)
                            for dt, dm in inst.result_shapes)
                cost.hbm_bytes += mult * 2.0 * b
            elif op not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "while", "call",
                            "conditional"):
                b = sum(_shape_bytes(dt, dm) for dt, dm in inst.result_shapes)
                cost.hbm_bytes += mult * 2.0 * b
            # collectives
            base = op.replace("-start", "")
            if base in COLLECTIVE_OPS:
                opr_b = 0
                for o in inst.operands:
                    sh = comp.shapes.get(o)
                    if sh:
                        opr_b += sum(_shape_bytes(dt, dm) for dt, dm in sh)
                res_b = sum(_shape_bytes(dt, dm) for dt, dm in inst.result_shapes)
                if opr_b == 0:
                    opr_b = res_b
                n = max(2, _group_size(inst.line, n_devices))
                eff = (n - 1) / n
                if base == "all-gather":
                    link = (res_b or opr_b * n) * eff
                elif base == "reduce-scatter":
                    link = opr_b * eff
                elif base == "all-reduce":
                    link = 2 * opr_b * eff
                elif base == "all-to-all":
                    link = opr_b * eff
                else:
                    link = opr_b
                cost.collective_bytes[base] += mult * link
                cost.collective_counts[base] += mult
            # control flow
            if op == "while":
                cost.n_while += 1
                bm = _ATTR_COMP_RE["body"].search(inst.line)
                cm = _ATTR_COMP_RE["condition"].search(inst.line)
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                if bm and bm.group(1) in comps:
                    visit(comps[bm.group(1)], mult * trips)
                if cm and cm.group(1) in comps:
                    visit(comps[cm.group(1)], mult * trips)
            elif op == "call":
                m = _ATTR_COMP_RE["to_apply"].search(inst.line)
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)], mult)
            elif op == "conditional":
                m = _ATTR_COMP_RE["branches"].search(inst.line)
                if m:
                    for b in _OPERAND_RE.findall(m.group(1)):
                        if b in comps:
                            visit(comps[b], mult)
            elif op == "fusion":
                # dots inside fusions still execute — count their flops,
                # but NOT their internal byte traffic.
                m = _ATTR_COMP_RE["calls"].search(inst.line)
                if m and m.group(1) in comps:
                    fc = comps[m.group(1)]
                    for fi in fc.instructions:
                        if fi.op == "dot":
                            cost.flops += mult * _dot_flops(fi, fc)
                        elif fi.op == "convolution":
                            res = sum(_dims(d) for _, d in fi.result_shapes)
                            cost.flops += mult * 2.0 * res
        seen_stack.pop()

    visit(entry, 1.0)
    return cost


# ------------------------------------------------------------------
# compatibility wrappers used by dryrun.py
# ------------------------------------------------------------------

def collective_bytes(hlo_text: str, n_devices: int) -> Dict[str, float]:
    c = analyze(hlo_text, n_devices)
    out = {f"bytes_{k}": v for k, v in c.collective_bytes.items()}
    out.update({f"count_{k}": c.collective_counts[k]
                for k in c.collective_counts})
    out["bytes_total"] = c.collective_total
    return out


def extract_cost(compiled) -> Dict[str, float]:
    out = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        for k, v in ca.items():
            if isinstance(v, (int, float)) and not k.startswith("utilization"):
                out[f"xla_{k.replace(' ', '_')}"] = float(v)
    except Exception as e:  # pragma: no cover
        out["cost_analysis_error"] = str(e)
    return out


def extract_memory(compiled) -> Dict[str, float]:
    out = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = float(v)
    except Exception as e:  # pragma: no cover
        out["memory_analysis_error"] = str(e)
    return out
