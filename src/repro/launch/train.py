"""Training launcher: data pipeline → sharded train step → checkpointed,
supervised loop (straggler detection + restart-on-failure).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 50 --batch 8 --seq 256

On the production mesh this runs under `make_production_mesh()` with the
same sharding rules as the dry-run; on this 1-core container it runs the
reduced (smoke) configs end-to-end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.checkpointer import Checkpointer
from ..configs.base import get_config, get_smoke_config
from ..data.pipeline import PipelineConfig, TokenPipeline
from ..models.api import build_model
from ..optim import adamw
from ..runtime.fault import Supervisor
from ..train.step import make_train_step


def build_trainer(cfg, batch: int, seq: int, lr: float = 3e-4,
                  accum_steps: int = 1):
    model = build_model(cfg)
    opt_cfg = adamw.AdamWConfig(lr=lr)
    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      accum_steps=accum_steps),
                      donate_argnums=(0, 1))
    return model, opt_cfg, step_fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model, opt_cfg, step_fn = build_trainer(cfg, args.batch, args.seq,
                                            args.lr, args.accum)
    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw.init(params)
    print(f"arch={cfg.name} params={model.n_params():,}")

    pipe = TokenPipeline(PipelineConfig(args.batch, args.seq, cfg.vocab))
    ckpt = Checkpointer(args.ckpt_dir, keep=2)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        (params, opt_state), start = ckpt.restore((params, opt_state))
        pipe.load_state_dict({"step": start})
        print(f"resumed from step {start}")

    def one_step(state, step):
        p, o = state
        batch = {"tokens": jnp.asarray(pipe._batch_at(step))}
        p, o, metrics = step_fn(p, o, batch)
        return (p, o), metrics

    sup = Supervisor(
        step_fn=one_step,
        save_fn=lambda s, st: ckpt.save(s, st),
        restore_fn=lambda: ckpt.restore((params, opt_state)),
        checkpoint_every=args.ckpt_every)

    t0 = time.time()
    (params, opt_state), step, history, restarts = sup.run(
        (params, opt_state), start, args.steps)
    ckpt.wait()
    losses = [float(h["loss"]) for h in history]
    dt = time.time() - t0
    toks = args.batch * args.seq * len(history)
    print(f"steps={step} loss[first..last]={losses[0]:.3f}..{losses[-1]:.3f}"
          f" tokens/s={toks/dt:,.0f} restarts={restarts}"
          f" stragglers={len(sup.straggler.events)}")
    return losses


if __name__ == "__main__":
    main()
