"""Assigned input-shape sets and ShapeDtypeStruct stand-ins.

Four shapes per LM architecture (see DESIGN.md §5 for skips):
    train_4k      seq 4096  × global_batch 256   (train_step)
    prefill_32k   seq 32768 × global_batch 32    (serve prefill)
    decode_32k    one token, KV cache 32768, batch 128   (serve decode)
    long_500k     one token, cache 524288, batch 1 — SSM/hybrid only

`input_specs(cfg, shape)` returns weak-type-correct, shardable
ShapeDtypeStructs — no device allocation ever happens in the dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

SDS = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq: int
    batch: int
    step: str      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cell_applicable(cfg: ArchConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.sub_quadratic   # full-attention archs skip (DESIGN.md §5)
    return True


def applicable_cells(cfg: ArchConfig):
    return [s for s in SHAPES if cell_applicable(cfg, s)]


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    B, S = shape.batch, shape.seq
    if cfg.family == "audio":
        return {"frames": SDS((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": SDS((B, cfg.dec_max_seq), jnp.int32)}
    if cfg.family == "vlm":
        sv = cfg.frontend_seq
        return {"tokens": SDS((B, S - sv), jnp.int32),
                "vision_embeds": SDS((B, sv, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((B, S), jnp.int32)}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, SDS]:
    B, S = shape.batch, shape.seq
    if cfg.family == "audio":
        return {"frames": SDS((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": SDS((B, cfg.dec_max_seq), jnp.int32)}
    if cfg.family == "vlm":
        sv = cfg.frontend_seq
        return {"tokens": SDS((B, S - sv), jnp.int32),
                "vision_embeds": SDS((B, sv, cfg.d_model), jnp.bfloat16)}
    return {"tokens": SDS((B, S), jnp.int32)}


def decode_token_specs(cfg: ArchConfig, shape: ShapeSpec):
    B = shape.batch
    token = SDS((B, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return token, pos


def abstract_caches(model, cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStructs for the serve caches (decode shapes)."""
    shapes = jax.eval_shape(
        lambda: model.init_caches(shape.batch, shape.seq))
    return shapes


def make_concrete_batch(cfg: ArchConfig, shape_name: str, key,
                        batch_override: Optional[int] = None,
                        seq_override: Optional[int] = None):
    """Small concrete batch for smoke tests / examples (not the dry-run)."""
    sp = SHAPES[shape_name]
    B = batch_override or sp.batch
    S = seq_override or sp.seq
    if cfg.family == "audio":
        k_frames, k_tokens = jax.random.split(key)
        return {"frames": jax.random.normal(k_frames, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(
                    k_tokens, (B, min(cfg.dec_max_seq, 64)), 0, cfg.vocab)}
    if cfg.family == "vlm":
        sv = min(cfg.frontend_seq, S // 2)
        k_tokens, k_vision = jax.random.split(key)
        return {"tokens": jax.random.randint(k_tokens, (B, S - sv), 0,
                                             cfg.vocab),
                "vision_embeds": jax.random.normal(
                    k_vision, (B, sv, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
