"""Shard-aware token data pipeline with prefetch and resumable state.

Sources: synthetic (deterministic per (seed, step) — reproducible across
restarts without any data-state checkpointing beyond the step counter) or
a binary token file (np.memmap).  Each data-parallel host reads only its
shard: `shard_id/num_shards` stride over the sequence stream, matching
the `("pod","data")` batch sharding of the training step.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class PipelineConfig:
    batch: int                  # per-host batch
    seq: int
    vocab: int
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    token_file: Optional[str] = None
    prefetch: int = 2


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self.step = 0
        self._mm = None
        if cfg.token_file:
            self._mm = np.memmap(cfg.token_file, dtype=np.int32, mode="r")
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # --- deterministic batch construction (resumable) ---
    def _batch_at(self, step: int) -> np.ndarray:
        c = self.cfg
        if self._mm is not None:
            tokens_per_batch = c.batch * (c.seq + 1)
            stride = tokens_per_batch * c.num_shards
            start = (step * stride + c.shard_id * tokens_per_batch) % \
                max(1, len(self._mm) - tokens_per_batch)
            flat = np.asarray(self._mm[start:start + tokens_per_batch])
            return flat.reshape(c.batch, c.seq + 1).astype(np.int32)
        rng = np.random.default_rng(
            (c.seed, step, c.shard_id))
        # zipf-ish synthetic distribution: heavy-tailed like text
        z = rng.zipf(1.3, size=(c.batch, c.seq + 1))
        return np.minimum(z, c.vocab - 1).astype(np.int32)

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = {"tokens": self._batch_at(step)}
            self._q.put((step, batch))
            step += 1

    def start(self):
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        return self

    def stop(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()

    def __iter__(self) -> Iterator[dict]:
        if self._thread is None:
            # synchronous fallback
            while True:
                yield {"tokens": self._batch_at(self.step)}
                self.step += 1
        else:
            while True:
                step, batch = self._q.get()
                self.step = step + 1
                yield batch

    # --- checkpointable state ---
    def state_dict(self):
        return {"step": self.step}

    def load_state_dict(self, s):
        self.step = int(s["step"])
