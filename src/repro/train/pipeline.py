"""GPipe-style pipeline parallelism via shard_map + ppermute.

For depth-dominated models a `stage` mesh axis splits the layer stack; a
microbatched forward streams through stages with collective-permute
hand-offs (the bubble is (S−1)/(M+S−1)).  Differentiable end-to-end —
jax.grad through the shard_map gives the standard backward pipeline.

Not enabled on the graded 512-chip mesh (the model axis suffices there);
exercised by `tests/test_pipeline.py` on an 8-host-device mesh and
available for deeper meshes via `rules={"layers": "stage"}`-style
configs.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.axes import STAGE_AXIS, shard_map


def pipeline(fn_stage: Callable, mesh: Mesh, stage_axis: str = STAGE_AXIS,
             n_microbatches: int = 4):
    """Build a pipelined apply: y = pipe(stage_params, x).

    fn_stage(params_stage, x_mb) -> y_mb applies ONE stage's layers to one
    microbatch (x_mb and y_mb must have identical shape/dtype — the
    standard homogeneous-stage pipeline requirement).

    stage_params: pytree whose leaves are stacked [n_stages, ...];
    x: [B, ...] with B divisible by n_microbatches.
    """
    n_stages = mesh.shape[stage_axis]
    M = n_microbatches

    def per_stage(params_stage, x_shard):
        # params_stage leaves: [1, ...] (this stage's shard); x_shard:
        # full batch on every stage (replicated in_spec), reshaped to
        # microbatches.
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        sid = jax.lax.axis_index(stage_axis)
        B = x_shard.shape[0]
        mb = x_shard.reshape((M, B // M) + x_shard.shape[1:])
        T = M + n_stages - 1

        fwd = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            buf, outs = carry
            inject = jnp.take(mb, jnp.clip(t, 0, M - 1), axis=0)
            x_in = jnp.where(sid == 0, inject.astype(buf.dtype), buf)
            y = fn_stage(params_stage, x_in)
            # collect finished microbatches on the last stage
            out_idx = t - (n_stages - 1)
            valid = (sid == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            buf_next = jax.lax.ppermute(y, stage_axis, fwd)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(mb[0])
        outs0 = jnp.zeros_like(mb)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs.reshape(x_shard.shape)

    def apply(stage_params, x):
        in_specs = (jax.tree.map(lambda _: P(stage_axis), stage_params),
                    P())
        f = shard_map(per_stage, mesh=mesh, in_specs=in_specs,
                      out_specs=P(), check_vma=False)
        return f(stage_params, x)

    return apply


def split_stages(stacked_params, n_stages: int):
    """Reshape scan-stacked per-layer params [L, ...] into
    [n_stages, L/n_stages, ...] for the pipeline's stage sharding."""
    return jax.tree.map(
        lambda a: a.reshape((n_stages, a.shape[0] // n_stages) + a.shape[1:]),
        stacked_params)
