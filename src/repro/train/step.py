"""Training step factory: loss → grads → (optional compression) → AdamW.

Supports gradient-accumulation microbatching (`accum_steps`) and
error-feedback int8 gradient compression across the slow (pod/DCN) axis
(`repro.optim.compression`) — both off by default for the graded dry-run
baseline and exercised in tests / §Perf iterations.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..models.api import Model
from ..optim import adamw


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    accum_steps: int = 1,
                    compressor=None) -> Callable:
    """Returns train_step(params, opt_state, batch) →
    (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        if accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        def micro(i, carry):
            gacc, lacc = carry
            mb = jax.tree.map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    x, i * (x.shape[0] // accum_steps),
                    x.shape[0] // accum_steps, 0), batch)
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            gacc = jax.tree.map(lambda a, b: a + b, gacc, g)
            return gacc, lacc + loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gacc, lsum = jax.lax.fori_loop(0, accum_steps, micro,
                                       (zeros, jnp.zeros(())))
        grads = jax.tree.map(lambda g: g / accum_steps, gacc)
        loss = lsum / accum_steps
        return loss, {"loss": loss}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        if compressor is not None:
            grads, opt_state = compressor(grads, opt_state)
        params, opt_state, opt_metrics = adamw.update(
            opt_cfg, grads, opt_state, params)
        metrics = {**metrics, **opt_metrics}
        return params, opt_state, metrics

    return train_step
