"""Logical-axis → mesh-axis sharding rules (MaxText-style).

Model code annotates parameters and activations with *logical* axis names;
a rule set maps them onto physical mesh axes.  Rules are swappable per
launch configuration (single-pod, multi-pod, long-context), which is how
the §Perf hillclimb iterates sharding without touching model code.

Besides the model meshes ("pod", "data", "model"), this module owns the
sweep meshes: `repro.core.sweep.sharded_sweep` and
`repro.core.mc_sweep.sharded_mc_sweep` shard their embarrassingly-parallel
grids over a named 2-D (`CONFIG_AXIS` × `TRIAL_AXIS`) mesh (`sweep_mesh`)
whose PartitionSpecs come from the `SWEEP_RULES` logical-axis table via
`spec_for` (`batch_spec` for flat batches, `grid_spec` for [B, T] trial
grids; the default (D, 1) shape reproduces the historical 1-D
`config_mesh` layout bitwise).  The version-portable `shard_map` wrapper
exported here is the one entry point the rest of the codebase uses.
"""
from __future__ import annotations

import contextlib
import inspect
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                  # jax ≥ 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                   # jax ≤ 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# the replication-check kwarg was renamed check_rep → check_vma in jax 0.7
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-portable `shard_map` (top-level vs experimental import,
    check_rep/check_vma kwarg rename)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: check_vma})


AxisVal = Union[None, str, Tuple[str, ...]]
Rules = Dict[str, AxisVal]

# ---------------------------------------------------------------------------
# Fleet-sweep configuration mesh (repro.core.sweep.sharded_sweep).
# ---------------------------------------------------------------------------

# Mesh-axis names for the sweep's (configuration × trial) grid.  The grid
# is embarrassingly parallel (one lifecycle per configuration/trial, no
# cross-config collectives), so mesh geometry is purely a placement
# choice: a 1-D `CONFIG_AXIS` mesh for flat configuration batches, or a
# 2-D (config × trial) mesh that spreads Monte-Carlo trial replicas over
# their own axis (multi-host fleets put `TRIAL_AXIS` on the fast
# intra-host interconnect; here it keeps per-device memory flat in both
# grid dimensions).
CONFIG_AXIS = "config"
TRIAL_AXIS = "trial"

# Pipeline-parallel stage axis (train/pipeline.py's GPipe mesh).  Every
# mesh-axis name used anywhere in the repo is declared in this module —
# `tools/repro_lint` rule RL601 rejects axis-name literals it cannot
# find here, so a typo'd axis can't silently replicate.
STAGE_AXIS = "stage"

# Logical-axis rules for the sweep engines (the levanter named-axis
# idiom: engine code names *logical* axes, this table maps them onto
# mesh axes, `spec_for` builds the PartitionSpecs).  "batch" is a flat
# (config·trial) axis product-sharded over BOTH mesh axes — on a (D, 1)
# mesh that is exactly the old 1-D `P(CONFIG_AXIS)` layout, so the 2-D
# generalization is bitwise-inert for flat batches.
SWEEP_RULES: Rules = {
    "config": CONFIG_AXIS,
    "trial": TRIAL_AXIS,
    "batch": (CONFIG_AXIS, TRIAL_AXIS),
}


def config_mesh(devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    """1-D device mesh over `devices` (default: all local devices) with the
    single axis `CONFIG_AXIS`, for sharding a sweep's configuration batch."""
    devs = list(devices) if devices is not None else list(jax.devices())
    return jax.make_mesh((len(devs),), (CONFIG_AXIS,), devices=devs)


def config_spec() -> P:
    """PartitionSpec sharding the leading (configuration) axis over
    `CONFIG_AXIS`; trailing dims replicated."""
    return spec_for(("config",), SWEEP_RULES)


def sweep_mesh(devices: Optional[Sequence[jax.Device]] = None,
               shape: Optional[Tuple[int, int]] = None) -> Mesh:
    """2-D (`CONFIG_AXIS` × `TRIAL_AXIS`) device mesh over `devices`.

    `shape=(dc, dt)` must multiply out to the device count; the default
    `(D, 1)` puts every device on the configuration axis, which makes
    flat-batch sharding under `batch_spec()` bitwise-identical to the
    historical 1-D `config_mesh` layout (same device order, same slabs).
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    D = len(devs)
    if shape is None:
        shape = (D, 1)
    dc, dt = int(shape[0]), int(shape[1])
    if dc < 1 or dt < 1 or dc * dt != D:
        raise ValueError(
            f"mesh shape {shape} needs {max(dc, 1) * max(dt, 1)} devices, "
            f"got {D}")
    return jax.make_mesh((dc, dt), (CONFIG_AXIS, TRIAL_AXIS), devices=devs)


def batch_spec() -> P:
    """PartitionSpec for a FLAT (config·trial) batch axis: product-sharded
    over both mesh axes (dc·dt-way)."""
    return spec_for(("batch",), SWEEP_RULES)


def grid_spec() -> P:
    """PartitionSpec for a [B, T] (config, trial) grid: configurations
    block-shard over `CONFIG_AXIS`, trials over `TRIAL_AXIS`."""
    return spec_for(("config", "trial"), SWEEP_RULES)

# Baseline rule set for the production mesh ("pod", "data", "model").
# DP over (pod×data); TP/EP/vocab over model; optimizer state additionally
# sharded over data (ZeRO-1) via OPT_OVERRIDES.
def base_rules(multi_pod: bool) -> Rules:
    data = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": data,
        "seq": None,
        "seq_kv": None,
        "embed": None,
        # residual-stream activations are sharded over `model` (Megatron-SP
        # style): XLA inserts all-gather before each projection and
        # reduce-scatter after, so scan-saved residuals cost 1/TP memory.
        "act_embed": "model",
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "mlp": "model",
        "expert": "model",
        "expert_cap": None,
        "vocab": "model",
        "layers": None,
        "ssm_heads": "model",
        "ssm_state": None,
        "ssm_inner": "model",
        "conv": None,
        "frontend": None,
    }


# ZeRO-1: optimizer moments additionally sharded over the data axes on the
# first data-shardable logical dim.
def _data_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def opt_overrides(multi_pod: bool) -> Rules:
    return {"embed": _data_axes(multi_pod), "layers": None}


def fsdp_rules(rules: Rules, multi_pod: bool) -> Rules:
    """ZeRO-3/FSDP: parameters themselves sharded over the data axes on
    their `embed` dim (per-layer all-gather at use, inserted by GSPMD)."""
    r = dict(rules)
    r["embed"] = _data_axes(multi_pod)
    return r


def pure_dp_rules(multi_pod: bool) -> Rules:
    """Full data parallelism: batch sharded across the mesh, weights
    replicated (optimizer still ZeRO-sharded).  The right regime for
    models whose parameters fit one chip (≲ 4B at bf16 on v5e): removes
    all per-layer TP collectives, leaving only the gradient reduction
    (§Perf qwen3/mamba2 iterations).

    Multi-pod: global batch 256 < 512 chips, so the batch shards 256-way
    over (data×model) and the sequence splits 2-way over the `pod` axis
    (context parallelism across the DCN — measured near-ideal 2× compute
    scaling for qwen3, §Perf)."""
    r: Rules = {k: None for k in base_rules(multi_pod)}
    if multi_pod:
        r["batch"] = ("data", "model")
        r["seq"] = "pod"
    else:
        r["batch"] = ("data", "model")
    return r


def sequence_parallel_rules(multi_pod: bool) -> Rules:
    """Long-context decode variant (long_500k, batch=1): the KV sequence is
    sharded over `model` (flash-decode style partial-softmax), while heads
    and SSM state occupy the otherwise-idle `data` axis.  Weights keep
    their TP sharding."""
    r = dict(base_rules(multi_pod))
    r["batch"] = None
    r["seq_kv"] = "model"
    r["heads"] = "data"
    r["kv_heads"] = "data"
    r["ssm_heads"] = "data"
    r["ssm_inner"] = "data"
    return r


_state = threading.local()


def set_rules(rules: Optional[Rules], mesh: Optional[Mesh] = None):
    _state.rules = rules
    _state.mesh = mesh


def get_rules() -> Optional[Rules]:
    return getattr(_state, "rules", None)


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_rules(rules: Rules, mesh: Optional[Mesh] = None):
    prev_r, prev_m = get_rules(), get_mesh()
    set_rules(rules, mesh)
    try:
        yield
    finally:
        set_rules(prev_r, prev_m)


def spec_for(axes: Sequence[Optional[str]], rules: Optional[Rules] = None) -> P:
    """Logical axes tuple → PartitionSpec under `rules`."""
    rules = rules if rules is not None else get_rules()
    if rules is None:
        return P()
    out, used = [], set()
    for a in axes:
        v = rules.get(a) if a is not None else None
        if v is None:
            out.append(None)
            continue
        vs = (v,) if isinstance(v, str) else tuple(v)
        vs = tuple(x for x in vs if x not in used)
        used.update(vs)
        out.append(vs if len(vs) > 1 else (vs[0] if vs else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def divisible_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop (or shrink to a divisible prefix) any axis mapping whose mesh
    extent does not divide the dimension — GSPMD requires exact
    divisibility for argument shardings.  Non-divisible cases (e.g. 40
    heads over a 16-way model axis) fall back to replication; §Perf
    iterations introduce arch-specific overrides instead."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None)
            continue
        axes_t = (entry,) if isinstance(entry, str) else tuple(entry)
        kept, k = [], 1
        for a in axes_t:
            if shape[i] % (k * sizes[a]) == 0:
                kept.append(a)
                k *= sizes[a]
        out.append(tuple(kept) if len(kept) > 1 else
                   (kept[0] if kept else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x, *axes):
    """Apply a sharding constraint if rules+mesh are active (no-op in plain
    CPU tests)."""
    rules, mesh = get_rules(), get_mesh()
    if rules is None or mesh is None:
        return x
    spec = divisible_spec(spec_for(axes, rules), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def tree_specs(axes_tree, rules: Optional[Rules] = None):
    """Axes pytree → PartitionSpec pytree."""
    return jax.tree.map(lambda a: spec_for(a, rules), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings(axes_tree, mesh: Mesh, rules: Optional[Rules] = None):
    return jax.tree.map(lambda a: NamedSharding(mesh, spec_for(a, rules)),
                        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def _is_axes_leaf(x):
    return isinstance(x, tuple) and all(a is None or isinstance(a, str)
                                        for a in x)


def tree_shardings_matched(axes_tree, abstract_tree, mesh: Mesh,
                           rules: Optional[Rules] = None):
    """Shape-aware shardings: like `tree_shardings` but drops mappings that
    don't divide the concrete dimension."""
    flat_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)
    flat_abs = treedef.flatten_up_to(abstract_tree)
    shardings = [
        NamedSharding(mesh, divisible_spec(spec_for(a, rules), s.shape, mesh))
        for a, s in zip(flat_axes, flat_abs)]
    return jax.tree.unflatten(treedef, shardings)


def opt_rules(rules: Rules, multi_pod: bool = False) -> Rules:
    r = dict(rules)
    r.update(opt_overrides(multi_pod))
    return r
