"""Placement feasibility + variance-min scoring Pallas TPU kernel.

The Monte Carlo studies (paper §4.4) evaluate, for every candidate row,
the redundancy admission condition (Eq. 1/2/26/27) and the
variance-minimization score — inside every scan step of every vmapped
trial.  This kernel fuses the per-row feed headroom checks and the score
reduction into one VMEM pass over row blocks.

Inputs are pre-gathered per row (HA/total loads and caps per feed,
padded with `valid=0`): the gather itself is XLA's job; the kernel owns
the dense math.  Scalars (deployment power P, ha_frac, tier/topology
flags) arrive as a small params vector broadcast to every block.

Semantics mirror `core.placement.row_feasible`'s power condition and
`row_scores`'s variance score term for term (the jnp path is the
bitwise oracle — see `tests/test_placement_kernel.py`):

* distributed HA:   every feed holds failover headroom
  ``load_ha + P/(k−1) ≤ ha_frac·C`` AND balanced-share room
  ``load_tot + P/k ≤ C``  (Eq. 1/27);
* distributed LA:   ``load_tot + P/k ≤ C`` (may consume reserve);
* block N+k:        ``load_tot + P ≤ C`` on the single primary (Eq. 2);
* row power fit:    ``row_load + P ≤ row_cap``;
* score:            ``Σ_feeds valid·(2·l̂·s + s²)``, ``s = (P/k)/C``,
  ``l̂`` the HA (HA tier) or total (LA tier) per-feed utilization.

The row grid pads to `block_r` tiles; padded rows are masked infeasible
(zero-valid feeds, negative row cap) and sliced off before returning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _score_kernel(loads_ha_ref, loads_tot_ref, caps_ref, valid_ref, nf_ref,
                  row_load_ref, row_cap_ref, params_ref, feas_ref,
                  score_ref):
    loads_ha = loads_ha_ref[...].astype(jnp.float32)   # [bR, F]
    loads_tot = loads_tot_ref[...].astype(jnp.float32)
    caps = caps_ref[...].astype(jnp.float32)
    valid = valid_ref[...].astype(jnp.float32)
    nf = nf_ref[...].astype(jnp.float32)               # [bR]
    row_load = row_load_ref[...].astype(jnp.float32)
    row_cap = row_cap_ref[...].astype(jnp.float32)
    p_dep = params_ref[0]
    ha_frac = params_ref[1]
    is_ha = params_ref[2]
    is_block = params_ref[3]

    share = p_dep / jnp.maximum(nf, 1.0)               # balanced share P/k
    delta = p_dep / jnp.maximum(nf - 1.0, 1.0)         # failover Δ (Eq. 1)
    tot_ok = loads_tot + share[:, None] <= caps + 1e-4
    ha_ok = (loads_ha + delta[:, None] <= ha_frac * caps + 1e-4) & tot_ok
    block_ok = loads_tot + p_dep <= caps + 1e-4        # quantization (Eq. 2)
    dist_ok = jnp.where(is_ha > 0, ha_ok, tot_ok)
    per_feed = jnp.where(is_block > 0, block_ok, dist_ok)
    power_ok = jnp.min(jnp.where(valid > 0, per_feed.astype(jnp.float32),
                                 1.0), axis=-1)
    fits = (row_load + p_dep <= row_cap + 1e-4).astype(jnp.float32)
    feas = power_ok * fits

    s = share[:, None] / jnp.maximum(caps, 1.0)
    lhat = jnp.where(is_ha > 0, loads_ha, loads_tot) / jnp.maximum(caps, 1.0)
    var = jnp.sum(valid * (2.0 * lhat * s + s * s), axis=-1)
    feas_ref[...] = feas
    score_ref[...] = jnp.where(feas > 0, var, BIG)


def placement_score(loads_ha, loads_tot, caps, valid, nf, row_load, row_cap,
                    params, block_r: int = 128, interpret: bool = False):
    """loads_ha/loads_tot/caps/valid: [R, F]; nf/row_load/row_cap: [R];
    params: [4] (P_dep, ha_frac, is_ha, is_block — the flags as 0/1
    floats).  Returns (feas [R] f32 0/1, score [R] f32; infeasible rows
    score `BIG`).

    The row axis is padded up to a multiple of ``min(block_r, R)``;
    padded rows carry zero-valid feeds and a negative row cap, so they
    come back infeasible and are sliced off before returning — callers
    never see them win a selection.
    """
    R, F = loads_ha.shape
    bR = max(1, min(block_r, R))
    R_pad = -(-R // bR) * bR
    if R_pad != R:
        n = R_pad - R
        rowpad = lambda x, fill: jnp.concatenate(
            [x, jnp.full((n,) + x.shape[1:], fill, x.dtype)])
        loads_ha = rowpad(loads_ha, 0.0)
        loads_tot = rowpad(loads_tot, 0.0)
        caps = rowpad(caps, 1.0)
        valid = rowpad(valid, 0.0)          # no feeds → power trivially ok…
        nf = rowpad(nf, jnp.zeros((), nf.dtype))
        row_load = rowpad(row_load, 0.0)
        row_cap = rowpad(row_cap, -1.0)     # …but the row itself never fits
    feas, score = pl.pallas_call(
        _score_kernel,
        grid=(R_pad // bR,),
        in_specs=[
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((bR,), lambda i: (i,)),
                   pl.BlockSpec((bR,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R_pad,), jnp.float32),
                   jax.ShapeDtypeStruct((R_pad,), jnp.float32)],
        interpret=interpret,
    )(loads_ha, loads_tot, caps, valid, nf, row_load, row_cap, params)
    return feas[:R], score[:R]
