"""Placement feasibility + variance-min scoring Pallas TPU kernel.

The single-hall Monte Carlo study (paper §4.4) evaluates, for every
candidate row, the distributed-redundancy admission condition (Eq. 1/27)
and the variance-minimization score — across thousands of vmapped trials.
This kernel fuses the per-row feed gathers, headroom checks and score
reduction into one VMEM pass over row blocks.

Inputs are pre-gathered per row (loads/caps per feed, padded with
`valid=0`): the gather itself is XLA's job; the kernel owns the dense
math.  Scalars (deployment power P, ha_frac) arrive as a small params
vector broadcast to every block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _score_kernel(loads_ref, caps_ref, valid_ref, nf_ref, row_load_ref,
                  row_cap_ref, params_ref, feas_ref, score_ref):
    loads = loads_ref[...].astype(jnp.float32)     # [bR, F]
    caps = caps_ref[...].astype(jnp.float32)
    valid = valid_ref[...].astype(jnp.float32)
    nf = nf_ref[...].astype(jnp.float32)           # [bR]
    row_load = row_load_ref[...].astype(jnp.float32)
    row_cap = row_cap_ref[...].astype(jnp.float32)
    p_dep = params_ref[0]
    ha_frac = params_ref[1]

    delta = p_dep / jnp.maximum(nf - 1.0, 1.0)     # Eq. 1
    head_ok = loads + delta[:, None] <= ha_frac * caps + 1e-4
    power_ok = jnp.min(jnp.where(valid > 0, head_ok.astype(jnp.float32),
                                 1.0), axis=-1)
    fits = (row_load + p_dep <= row_cap + 1e-4).astype(jnp.float32)
    feas = power_ok * fits

    s = (p_dep / jnp.maximum(nf, 1.0))[:, None] / jnp.maximum(caps, 1.0)
    lhat = loads / jnp.maximum(caps, 1.0)
    var = jnp.sum(valid * (2.0 * lhat * s + s * s), axis=-1)
    feas_ref[...] = feas
    score_ref[...] = jnp.where(feas > 0, var, BIG)


def placement_score(loads, caps, valid, nf, row_load, row_cap, params,
                    block_r: int = 128, interpret: bool = False):
    """loads/caps/valid: [R, F]; nf/row_load/row_cap: [R]; params: [2]
    (P_dep, ha_frac).  Returns (feas [R] f32 0/1, score [R] f32)."""
    R, F = loads.shape
    bR = min(block_r, R)
    while R % bR:
        bR //= 2
    return pl.pallas_call(
        _score_kernel,
        grid=(R // bR,),
        in_specs=[
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR, F), lambda i: (i, 0)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((bR,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((bR,), lambda i: (i,)),
                   pl.BlockSpec((bR,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.float32),
                   jax.ShapeDtypeStruct((R,), jnp.float32)],
        interpret=interpret,
    )(loads, caps, valid, nf, row_load, row_cap, params)
