"""Jitted wrapper: builds kernel inputs from a placement state."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import placement_score


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def score_rows(jt_row_feeds, jt_row_nfeeds, jt_row_cap_kw, lineup_ha,
               lineup_cap, row_load_kw, p_dep, ha_frac,
               block_r: int = 128, interpret: bool = False):
    """Gathers per-feed line-up state and runs the kernel.
    Returns (feas [R] bool, score [R])."""
    valid = (jt_row_feeds >= 0).astype(jnp.float32)
    safe = jnp.where(jt_row_feeds >= 0, jt_row_feeds, 0)
    loads = lineup_ha[safe]
    caps = lineup_cap[safe]
    params = jnp.stack([jnp.asarray(p_dep, jnp.float32),
                        jnp.asarray(ha_frac, jnp.float32)])
    feas, score = placement_score(
        loads, caps, valid, jt_row_nfeeds, row_load_kw, jt_row_cap_kw,
        params, block_r=block_r, interpret=interpret)
    return feas > 0, score
