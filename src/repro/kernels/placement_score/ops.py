"""Jitted wrapper: builds kernel inputs from a placement state.

Float32 contract: the kernel computes in float32 (TPU VMEM tiles), and
the placement engine's jnp oracle also runs in float32 — so the wrapper
*requires* float32 (or weaker) float inputs.  Callers running under
`jax.config.update("jax_enable_x64", True)` must down-cast explicitly;
a silent cast here would let the kernel drift bitwise from an x64
oracle, which is exactly what the equivalence harness exists to rule
out.  Integer inputs are converted to int32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import placement_score


def _require_f32(name, x):
    x = jnp.asarray(x)
    if x.dtype == jnp.float64:
        raise TypeError(
            f"score_rows: `{name}` is float64; the placement-score kernel "
            "computes in float32 (see module docstring). Cast inputs to "
            "float32 explicitly before calling.")
    return x.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def score_rows(jt_row_feeds, jt_row_nfeeds, jt_row_cap_kw, lineup_ha,
               lineup_tot, lineup_cap, row_load_kw, p_dep, ha_frac,
               is_ha, is_block, block_r: int = 128,
               interpret: bool = False):
    """Gathers per-feed line-up state and runs the kernel.

    `jt_row_feeds` may be a compacted subset view ([K, F] gathered at
    `hd_index[:K]`, with the other row arrays gathered to match) — the
    kernel itself is agnostic to row identity.  `is_ha`/`is_block` are
    0/1 flags (traced; deployment tier and topology family).  Returns
    (feas [R] bool, score [R] f32; infeasible rows score `kernel.BIG`).
    """
    jt_row_feeds = jnp.asarray(jt_row_feeds, jnp.int32)
    jt_row_nfeeds = jnp.asarray(jt_row_nfeeds, jnp.int32)
    jt_row_cap_kw = _require_f32("jt_row_cap_kw", jt_row_cap_kw)
    lineup_ha = _require_f32("lineup_ha", lineup_ha)
    lineup_tot = _require_f32("lineup_tot", lineup_tot)
    lineup_cap = _require_f32("lineup_cap", lineup_cap)
    row_load_kw = _require_f32("row_load_kw", row_load_kw)
    p_dep = _require_f32("p_dep", p_dep)
    ha_frac = _require_f32("ha_frac", ha_frac)

    valid = (jt_row_feeds >= 0).astype(jnp.float32)
    safe = jnp.where(jt_row_feeds >= 0, jt_row_feeds, 0)
    loads_ha = lineup_ha[safe]
    loads_tot = lineup_tot[safe]
    caps = lineup_cap[safe]
    params = jnp.stack([p_dep, ha_frac,
                        jnp.asarray(is_ha, jnp.float32).reshape(()),
                        jnp.asarray(is_block, jnp.float32).reshape(())])
    feas, score = placement_score(
        loads_ha, loads_tot, caps, valid, jt_row_nfeeds, row_load_kw,
        jt_row_cap_kw, params, block_r=block_r, interpret=interpret)
    return feas > 0, score
