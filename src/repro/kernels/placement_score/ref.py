"""Oracle for the placement-score kernel (mirrors core.placement math)."""
from __future__ import annotations

import jax.numpy as jnp

BIG = 1e30


def reference_score(loads, caps, valid, nf, row_load, row_cap, params):
    loads = loads.astype(jnp.float32)
    caps = caps.astype(jnp.float32)
    valid = valid.astype(jnp.float32)
    nf = nf.astype(jnp.float32)
    p_dep, ha_frac = params[0], params[1]

    delta = p_dep / jnp.maximum(nf - 1.0, 1.0)
    head_ok = loads + delta[:, None] <= ha_frac * caps + 1e-4
    power_ok = jnp.all(head_ok | (valid <= 0), axis=-1)
    fits = row_load + p_dep <= row_cap + 1e-4
    feas = (power_ok & fits).astype(jnp.float32)

    s = (p_dep / jnp.maximum(nf, 1.0))[:, None] / jnp.maximum(caps, 1.0)
    lhat = loads / jnp.maximum(caps, 1.0)
    var = jnp.sum(valid * (2.0 * lhat * s + s * s), axis=-1)
    return feas, jnp.where(feas > 0, var, BIG)
