"""Oracle for the placement-score kernel (mirrors core.placement math)."""
from __future__ import annotations

import jax.numpy as jnp

BIG = 1e30


def reference_score(loads_ha, loads_tot, caps, valid, nf, row_load, row_cap,
                    params):
    """Pure-jnp mirror of `kernel.placement_score` on one [R, F] block.

    Same argument convention as the kernel (all f32; params =
    [p_dep, ha_frac, is_ha, is_block]); no padding/tiling — this is the
    bitwise ground truth the Pallas path is tested against.
    """
    loads_ha = loads_ha.astype(jnp.float32)
    loads_tot = loads_tot.astype(jnp.float32)
    caps = caps.astype(jnp.float32)
    valid = valid.astype(jnp.float32)
    nf = nf.astype(jnp.float32)
    p_dep, ha_frac, is_ha, is_block = (params[0], params[1], params[2],
                                       params[3])

    share = p_dep / jnp.maximum(nf, 1.0)
    delta = p_dep / jnp.maximum(nf - 1.0, 1.0)
    tot_ok = loads_tot + share[:, None] <= caps + 1e-4
    ha_ok = (loads_ha + delta[:, None] <= ha_frac * caps + 1e-4) & tot_ok
    block_ok = loads_tot + p_dep <= caps + 1e-4
    dist_ok = jnp.where(is_ha > 0, ha_ok, tot_ok)
    per_feed = jnp.where(is_block > 0, block_ok, dist_ok)
    power_ok = jnp.all(per_feed | (valid <= 0), axis=-1)
    fits = row_load + p_dep <= row_cap + 1e-4
    feas = (power_ok & fits).astype(jnp.float32)

    s = share[:, None] / jnp.maximum(caps, 1.0)
    lhat = jnp.where(is_ha > 0, loads_ha, loads_tot) / jnp.maximum(caps, 1.0)
    var = jnp.sum(valid * (2.0 * lhat * s + s * s), axis=-1)
    return feas, jnp.where(feas > 0, var, BIG)
