"""Fused MoE router Pallas TPU kernel: softmax → top-k → renormalize.

One fused VMEM pass per token block: avoids materializing the [N, E]
softmax + separate top-k sweeps on HBM.  k is static and small (≤ 8 for
the assigned archs), so top-k is an unrolled iterative argmax.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gating_kernel(logits_ref, gate_ref, idx_ref, *, top_k: int):
    logits = logits_ref[...].astype(jnp.float32)          # [bn, E]
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / p.sum(axis=-1, keepdims=True)

    remaining = probs
    total = jnp.zeros((probs.shape[0],), jnp.float32)
    gates, idxs = [], []
    for _ in range(top_k):
        g = remaining.max(axis=-1)
        i = jnp.argmax(remaining, axis=-1).astype(jnp.int32)
        gates.append(g)
        idxs.append(i)
        total = total + g
        remaining = jnp.where(
            jax.lax.broadcasted_iota(jnp.int32, remaining.shape, 1)
            == i[:, None], NEG_INF, remaining)
    gate = jnp.stack(gates, axis=-1) / jnp.maximum(total, 1e-9)[:, None]
    gate_ref[...] = gate.astype(gate_ref.dtype)
    idx_ref[...] = jnp.stack(idxs, axis=-1)


def gating_topk(logits, top_k: int, block_n: int = 256,
                interpret: bool = False):
    """logits: [N, E] → (gate [N,k] f32 renormalized, idx [N,k] int32)."""
    N, E = logits.shape
    bn = min(block_n, N)
    while N % bn:
        bn //= 2
    kernel = functools.partial(_gating_kernel, top_k=top_k)
    return pl.pallas_call(
        kernel,
        grid=(N // bn,),
        in_specs=[pl.BlockSpec((bn, E), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bn, top_k), lambda i: (i, 0)),
                   pl.BlockSpec((bn, top_k), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((N, top_k), jnp.float32),
                   jax.ShapeDtypeStruct((N, top_k), jnp.int32)],
        interpret=interpret,
    )(logits)
