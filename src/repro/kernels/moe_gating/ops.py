"""Jitted wrapper for the fused gating kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import gating_topk


@functools.partial(jax.jit, static_argnames=("top_k", "block_n", "interpret"))
def fused_gating(logits, top_k: int, block_n: int = 256,
                 interpret: bool = False):
    N, E = logits.shape
    pad = 0
    if N % max(1, min(block_n, N)):
        bn = min(block_n, N)
        pad = (-N) % bn
        logits = jnp.pad(logits, ((0, pad), (0, 0)))
    gate, idx = gating_topk(logits, top_k, block_n, interpret)
    if pad:
        gate, idx = gate[:N], idx[:N]
    return gate, idx
