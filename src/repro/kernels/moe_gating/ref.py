"""Oracle: jax.nn.softmax + lax.top_k + renormalize."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_gating(logits, top_k: int):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    return gate, idx.astype(jnp.int32)
