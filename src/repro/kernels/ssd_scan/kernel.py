"""Mamba2 SSD intra-chunk Pallas TPU kernel.

Computes, per (batch, chunk, head-block) grid cell, the chunk-local SSD
quantities on the MXU:
    y_intra[q] = Σ_{k≤q} (C_q·B_k) · e^{A_q−A_k} · xdt_k
    h_chunk    = Σ_k e^{A_Q−A_k} · B_k ⊗ xdt_k       (chunk state summary)
    a_chunk    = e^{A_Q}                              (chunk decay)
The cheap inter-chunk recurrence over `h_chunk` runs outside (ops.py),
matching the SSD decomposition (DESIGN.md §3 TPU adaptation).

Block shapes: chunk Q × head-block HB × head-dim hd tiles sized for VMEM
(decay tensor is [Q, Q, HB] f32 — keep Q·Q·HB ≲ 2M elements).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, loga_ref, b_ref, c_ref, y_ref, h_ref, a_ref):
    xdt = xdt_ref[0].astype(jnp.float32)       # [Q, HB, hd]
    loga = loga_ref[0].astype(jnp.float32)     # [Q, HB]
    b = b_ref[0].astype(jnp.float32)           # [Q, st]
    c = c_ref[0].astype(jnp.float32)           # [Q, st]

    acum = jnp.cumsum(loga, axis=0)            # [Q, HB]
    s_qk = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [Q,Q]
    gap = acum[:, None, :] - acum[None, :, :]  # [Q, Q, HB]
    Q = xdt.shape[0]
    causal = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >=
              jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    # mask before exp (future-side gap is large-positive; inf·0 ⇒ NaN in
    # the vjp) — mirrors the jnp oracle
    decay = jnp.exp(jnp.where(causal[:, :, None], gap, -1e9))
    w = s_qk[:, :, None] * decay               # [Q, Q, HB]
    y = jnp.einsum("qkh,khd->qhd", w, xdt,
                   preferred_element_type=jnp.float32)

    tail = jnp.exp(acum[-1:, :] - acum)        # [Q, HB]
    h = jnp.einsum("kh,ks,khd->hds", tail, b, xdt,
                   preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    h_ref[0, 0] = h
    a_ref[0, 0] = jnp.exp(acum[-1])


def ssd_intra_chunk(xdt, log_a, b, c, *, chunk: int, head_block: int = 8,
                    interpret: bool = False):
    """xdt: [B,S,nh,hd]; log_a: [B,S,nh]; b,c: [B,S,st].  S = nC·chunk.
    Returns (y_intra [B,S,nh,hd] f32, h_chunk [B,nC,nh,hd,st] f32,
    a_chunk [B,nC,nh] f32)."""
    B, S, nh, hd = xdt.shape
    st = b.shape[-1]
    Q = chunk
    nC = S // Q
    hb = min(head_block, nh)
    while nh % hb:
        hb //= 2
    nH = nh // hb

    grid = (B, nC, nH)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, hb, hd), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, Q, hb), lambda bi, ci, hi: (bi, ci, hi)),
            pl.BlockSpec((1, Q, st), lambda bi, ci, hi: (bi, ci, 0)),
            pl.BlockSpec((1, Q, st), lambda bi, ci, hi: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, hb, hd), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, hb, hd, st),
                         lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, hb), lambda bi, ci, hi: (bi, ci, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, nh, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, nC, nh, hd, st), jnp.float32),
            jax.ShapeDtypeStruct((B, nC, nh), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, log_a, b, c)
