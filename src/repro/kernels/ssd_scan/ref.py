"""Independent oracle: naive per-timestep SSD recurrence (O(S) scan)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_ssd(xdt, log_a, b, c):
    """xdt: [B,S,nh,hd]; log_a: [B,S,nh]; b,c: [B,S,st] →
    y [B,S,nh,hd] f32 via h_t = e^{log_a_t}·h_{t-1} + xdt_t ⊗ b_t,
    y_t = h_t · c_t."""
    B, S, nh, hd = xdt.shape
    st = b.shape[-1]

    def step(h, inp):
        x_t, a_t, b_t, c_t = inp
        h = h * jnp.exp(a_t)[..., None, None] + \
            jnp.einsum("bhd,bs->bhds", x_t.astype(jnp.float32),
                       b_t.astype(jnp.float32))
        y = jnp.einsum("bhds,bs->bhd", h, c_t.astype(jnp.float32))
        return h, y

    h0 = jnp.zeros((B, nh, hd, st), jnp.float32)
    xs = (jnp.moveaxis(xdt, 1, 0), jnp.moveaxis(log_a, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1)
