"""Jitted SSD wrapper: Pallas intra-chunk kernel + jnp inter-chunk scan."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk


@functools.partial(jax.jit, static_argnames=("chunk", "head_block",
                                             "interpret"))
def ssd_scan(xdt, log_a, b, c, chunk: int = 128, head_block: int = 8,
             interpret: bool = False):
    """Full SSD: y [B,S,nh,hd] (f32).  Pads S to a chunk multiple (pads are
    identity steps: xdt=0, log_a=0)."""
    B, S, nh, hd = xdt.shape
    st = b.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        xdt, log_a, b, c = zf(xdt), zf(log_a), zf(b), zf(c)
    Sp = S + pad
    nC = Sp // Q

    y_intra, h_chunk, a_chunk = ssd_intra_chunk(
        xdt, log_a, b, c, chunk=Q, head_block=head_block,
        interpret=interpret)

    # inter-chunk recurrence (cheap): h after chunk i
    def step(h, inp):
        hc, ac = inp
        return h * ac[..., None, None] + hc, h
    h0 = jnp.zeros((B, nh, hd, st), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(h_chunk, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)            # [B,nC,nh,hd,st]

    acum = jnp.cumsum(log_a.reshape(B, nC, Q, nh), axis=2)
    y_inter = jnp.einsum("bnqs,bnhds,bnqh->bnqhd",
                         c.reshape(B, nC, Q, st).astype(jnp.float32),
                         h_prevs, jnp.exp(acum))
    y = y_intra.reshape(B, nC, Q, nh, hd) + y_inter
    return y.reshape(B, Sp, nh, hd)[:, :S]
