"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reference_attention(q, k, v, causal=True):
    """q: [B,H,Sq,hd]; k,v: [B,Hk,Skv,hd]; GQA via head repeat."""
    B, H, Sq, hd = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    G = H // Hk
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
