"""Flash-attention Pallas TPU kernel.

Grid (B, H, nq, nk) — the innermost axis walks KV blocks while VMEM
scratch carries the online-softmax state (m, l, acc); output is written on
the last KV block.  GQA is handled in the k/v index maps (h → h//G), so
K/V are never materialized per-query-head.  Block shapes are explicit
`BlockSpec`s; matmul dims should be multiples of 128 for the MXU (the
wrapper pads).

Target: TPU (HBM→VMEM tiling).  Validated on CPU via interpret=True
against `ref.reference_attention`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, kv_len: int,
                  block_q: int, block_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)                  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq,bk]
    rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    cols = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cols < kv_len
    if causal:
        mask = mask & (rows >= cols)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, kv_len: int,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: [B,H,Sq,hd]; k,v: [B,Hk,Skv,hd] (Sq, Skv already padded to block
    multiples; `kv_len` masks the padding)."""
    B, H, Sq, hd = q.shape
    Hk, Skv = k.shape[1], k.shape[2]
    G = H // Hk
    nq, nk = Sq // block_q, Skv // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, kv_len=kv_len,
        block_q=block_q, block_k=block_k)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
