"""Jitted wrapper: BSHD layout, padding to block multiples, GQA."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False):
    """q: [B,S,H,hd]; k,v: [B,S,Hk,hd] (model layout).  Returns [B,S,H,hd]."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    bq = min(block_q, max(8, 1 << (Sq - 1).bit_length()))
    bk = min(block_k, max(8, 1 << (Skv - 1).bit_length()))
    qt = _pad_to(jnp.swapaxes(q, 1, 2), 2, bq)
    kt = _pad_to(jnp.swapaxes(k, 1, 2), 2, bk)
    vt = _pad_to(jnp.swapaxes(v, 1, 2), 2, bk)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, kv_len=Skv,
                               block_q=bq, block_k=bk, interpret=interpret)
    return jnp.swapaxes(out[:, :, :Sq], 1, 2)
