"""Architecture configuration and registry.

One `ArchConfig` describes any of the supported model families
(dense / MoE / SSM / hybrid / VLM / enc-dec audio).  Each assigned
architecture lives in its own module (`repro.configs.<id>`) exposing
`CONFIG` (exact published parameters) and `smoke_config()` (a reduced
same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

ARCH_IDS = (
    "moonshot-v1-16b-a3b",
    "granite-moe-1b-a400m",
    "qwen3-1.7b",
    "qwen3-14b",
    "phi4-mini-3.8b",
    "nemotron-4-15b",
    "qwen2-vl-2b",
    "jamba-1.5-large-398b",
    "mamba2-2.7b",
    "whisper-small",
)

_MODULES = {a: a.replace("-", "_").replace(".", "p") for a in ARCH_IDS}


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (Jamba-style)
    attn_period: int = 0         # one attention layer per `attn_period`
    attn_offset: int = 0         # index of the attention layer in a period
    moe_period: int = 0          # MoE FFN every `moe_period` layers

    # attention details
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None  # M-RoPE (VLM)
    attn_logits_soft_cap: float = 0.0

    act: str = "swiglu"          # swiglu | sq_relu | gelu

    # encoder-decoder (Whisper)
    n_enc_layers: int = 0
    enc_max_seq: int = 0
    dec_max_seq: int = 448

    # modality frontend stub: input_specs() provides precomputed embeddings
    frontend: str = "none"       # none | vision_stub | audio_stub
    frontend_seq: int = 0        # vision/audio prefix length (train shapes)

    tie_embeddings: bool = False
    fsdp: bool = False          # shard params over data axes too (ZeRO-3)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "full"          # none | full | dots
    scan_layers: bool = True
    use_flash_kernel: bool = False   # Pallas path (TPU target)

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0 and self.top_k > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid; see DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        return True   # all assigned archs have a decoder

    def n_params_estimate(self) -> int:
        """Rough parameter count (embeddings + blocks), for MODEL_FLOPS."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_attn = (self.n_heads + 2 * self.n_kv_heads) * self.hd * d + \
            self.n_heads * self.hd * d
        mlp_mats = 3 if self.act == "swiglu" else 2
        per_mlp = mlp_mats * d * self.d_ff
        per_moe = self.n_experts * per_mlp + d * self.n_experts
        per_mamba = (2 * self.d_inner + 2 * self.ssm_state + self.ssm_heads) * d \
            + self.d_inner * d
        if self.family == "ssm":
            body = L * per_mamba
        elif self.family == "hybrid":
            n_attn = L // max(self.attn_period, 1)
            n_moe = L // max(self.moe_period, 1)
            body = (n_attn * per_attn + (L - n_attn) * per_mamba
                    + n_moe * per_moe + (L - n_moe) * per_mlp)
        else:
            n_enc = self.n_enc_layers
            ffn = per_moe if self.is_moe else per_mlp
            body = L * (per_attn + ffn)
            body += n_enc * (per_attn + per_mlp)      # encoder stack
            body += self.n_layers * per_attn * (1 if n_enc else 0)  # cross-attn
        return emb + body

    def active_params_estimate(self) -> int:
        if not (self.is_moe or self.is_hybrid):
            return self.n_params_estimate()
        cfg_active = replace(self, n_experts=self.top_k)
        return cfg_active.n_params_estimate()


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()
