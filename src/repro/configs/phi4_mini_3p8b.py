"""phi4-mini-3.8b — RoPE + SwiGLU + GQA [arXiv:2412.08905]."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab=200064,
    act="swiglu", rope_theta=1e4,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                   d_ff=192, vocab=512, remat="none")
