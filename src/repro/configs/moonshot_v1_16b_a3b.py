"""moonshot-v1-16b-a3b — Moonlight-16B-A3B-style MoE
[hf:moonshotai/Moonlight-16B-A3B].  64 experts, top-6, GQA kv=16 (=MHA at
16 heads).  d_ff is the per-expert FF width."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=163840, n_experts=64, top_k=6,
    qk_norm=False, act="swiglu", rope_theta=5e4,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=96, vocab=512, n_experts=8, top_k=2, remat="none")
