"""qwen3-14b — Qwen3 dense, qk-norm + GQA [hf:Qwen/Qwen3-8B family]."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, head_dim=128,
    qk_norm=True, act="swiglu", rope_theta=1e6,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                   d_ff=160, vocab=512, head_dim=16, remat="none")
