"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE
16e top-2 [arXiv:2403.19887].  Attention at index 3 of each 8-layer period
(Jamba convention); MoE FFN on alternate layers.  The Mamba mixer uses the
Mamba2/SSD formulation (TPU adaptation, DESIGN.md §3) with Jamba's
d_state=16."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=24576, vocab=65536, n_experts=16, top_k=2,
    attn_period=8, attn_offset=3, moe_period=2,
    ssm_state=16, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
    act="swiglu", rope_theta=1e4, fsdp=True,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=96, vocab=512, n_experts=4, top_k=2,
                   attn_period=4, attn_offset=1, moe_period=2,
                   ssm_state=8, ssm_headdim=16, remat="none")
