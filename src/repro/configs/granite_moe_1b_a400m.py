"""granite-moe-1b-a400m — IBM Granite 3.0 1B-A400M MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base].  32 experts, top-8, GQA kv=8."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab=49155, n_experts=32, top_k=8,
    act="swiglu", rope_theta=1e4,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=64, vocab=512, n_experts=4, top_k=2, remat="none")
