"""nemotron-4-15b — GQA + squared-ReLU MLP [arXiv:2402.16819]."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000,
    act="sq_relu", rope_theta=1e4,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
                   d_ff=256, vocab=512, remat="none")
