"""mamba2-2.7b — attention-free SSD (state-space duality)
[arXiv:2405.21060].  d_inner = 2·d_model = 5120, 80 heads × headdim 64,
d_state=128."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=128,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=64, vocab=512,
                   ssm_state=16, ssm_headdim=16, remat="none")
