"""whisper-small — encoder-decoder audio backbone [arXiv:2212.04356].
Conv frontend is a STUB: input_specs() provides precomputed frame
embeddings; decoder context 448."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865,
    n_enc_layers=12, dec_max_seq=448,
    act="gelu", frontend="audio_stub",
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, n_enc_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
                   dec_max_seq=32, remat="none")
