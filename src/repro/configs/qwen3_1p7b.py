"""qwen3-1.7b — Qwen3 dense with qk-norm + GQA [hf:Qwen/Qwen3-8B family]."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-1.7b", family="dense",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8,
    d_ff=6144, vocab=151936, head_dim=128,
    qk_norm=True, act="swiglu", rope_theta=1e6,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=512, head_dim=16, remat="none")
