"""qwen2-vl-2b — VLM backbone with M-RoPE [arXiv:2409.12191].  The vision
frontend is a STUB: input_specs() provides precomputed patch embeddings."""
from dataclasses import replace

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    act="swiglu", rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    frontend="vision_stub", frontend_seq=256,
)


def smoke_config() -> ArchConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=512, mrope_sections=(2, 3, 3),
                   frontend_seq=8, remat="none")
