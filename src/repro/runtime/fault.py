"""Failure handling and straggler mitigation for long training runs.

At fleet scale the failure model is: nodes die (checkpoint/restart),
nodes slow down (stragglers → deadline-based detection and re-dispatch),
and device sets change across restarts (elastic re-shard, see
`runtime.elastic`).  This module provides the supervisor loop that a real
multi-host launcher wraps around `jax.distributed` — exercised here with
simulated failures (exceptions / injected delays).
"""
from __future__ import annotations

import logging
import statistics
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional

log = logging.getLogger("repro.fault")


class NodeFailure(RuntimeError):
    """Raised by a step function when a worker is lost."""


@dataclass(frozen=True)
class Backoff:
    """Exponential-backoff retry schedule: attempt `i` (0-based) sleeps
    `min(base_s * factor**i, cap_s)` before retrying, for up to
    `max_retries` retries after the first attempt.  Shared by the
    resilient sweep executor (`repro.core.resilience`) and any
    supervisor retry loop; `base_s=0` keeps test schedules instant
    while preserving the retry count."""
    base_s: float = 0.05
    factor: float = 2.0
    cap_s: float = 5.0
    max_retries: int = 3

    def delay(self, attempt: int) -> float:
        """Sleep before retry `attempt` (0-based)."""
        return min(self.base_s * self.factor ** attempt, self.cap_s)

    def delays(self):
        """The full schedule, one delay per allowed retry."""
        return [self.delay(i) for i in range(self.max_retries)]

    def sleep(self, attempt: int) -> None:
        d = self.delay(attempt)
        if d > 0:
            time.sleep(d)


@dataclass
class StragglerPolicy:
    """Deadline-based straggler detection: a step slower than
    `threshold × median` of the trailing window is flagged; after
    `max_flags` consecutive flags the mitigation hook fires (on a real
    fleet: re-dispatch the slow host's shard / drop to checkpoint)."""
    window: int = 16
    threshold: float = 2.5
    max_flags: int = 3
    _times: List[float] = field(default_factory=list)
    _flags: int = 0
    _last_flag_step: int = -2
    events: List[dict] = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; True ⇒ fire the mitigation hook.

        "Consecutive" means consecutive *steps*: any fast step — and any
        gap in the observed step sequence (restart, skipped steps) —
        resets the streak, so `max_flags` slow steps scattered over an
        hour never accumulate into a firing.
        """
        self._times.append(seconds)
        self._times = self._times[-self.window:]
        if len(self._times) < 4:
            return False
        med = statistics.median(self._times[:-1])
        slow = seconds > self.threshold * med
        if not slow or step != self._last_flag_step + 1:
            self._flags = 0          # streak broken: fast step or step gap
        if slow:
            self._flags += 1
            self._last_flag_step = step
            self.events.append({"step": step, "seconds": seconds,
                                "median": med})
            if self._flags >= self.max_flags:
                self._flags = 0
                return True
        return False


@dataclass
class Supervisor:
    """Checkpoint/restart supervisor around a step function.

    step_fn(state, step) -> (state, metrics); save_fn(step, state);
    restore_fn() -> (state, step).
    """
    step_fn: Callable
    save_fn: Callable
    restore_fn: Callable
    checkpoint_every: int = 50
    max_restarts: int = 5
    straggler: StragglerPolicy = field(default_factory=StragglerPolicy)
    on_straggler: Optional[Callable] = None
    # zero base delay: restart loops in tests stay instant but still
    # honor the schedule shape when a real deployment raises base_s
    backoff: Backoff = field(default_factory=lambda: Backoff(base_s=0.0))

    def run(self, state, start_step: int, num_steps: int):
        step = start_step
        restarts = 0
        history = []
        while step < num_steps:
            try:
                t0 = time.time()
                state, metrics = self.step_fn(state, step)
                dt = time.time() - t0
                if self.straggler.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step)
                history.append(metrics)
                step += 1
                if step % self.checkpoint_every == 0:
                    self.save_fn(step, state)
            except NodeFailure as e:
                restarts += 1
                log.warning("node failure at step %d (%s); restart %d/%d",
                            step, e, restarts, self.max_restarts)
                if restarts > self.max_restarts:
                    raise
                self.backoff.sleep(restarts - 1)
                state, step = self.restore_fn()
        self.save_fn(step, state)
        return state, step, history, restarts
