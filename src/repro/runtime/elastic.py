"""Elastic re-meshing: resume a job on a different device set.

A checkpoint written under mesh A restores under mesh B by re-deriving
shardings from the *logical axes* (which are mesh-independent) and
`device_put`-ing each leaf — the standard recovery path when nodes are
lost (shrink) or capacity is added (grow)."""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh

from ..sharding import axes as ax


def make_mesh_from(devices: Sequence, shape, axis_names) -> Mesh:
    n = 1
    for s in shape:
        n *= s
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(shape, axis_names, devices=list(devices)[:n])


def survivors_mesh(failed: Sequence[int], shape, axis_names) -> Mesh:
    """Rebuild a (smaller) mesh after losing device indices `failed` —
    simulates node loss on the host platform."""
    alive = [d for i, d in enumerate(jax.devices()) if i not in set(failed)]
    return make_mesh_from(alive, shape, axis_names)


def reshard(tree: Any, axes_tree: Any, mesh: Mesh, rules: ax.Rules):
    """Re-place every leaf under `mesh` according to its logical axes."""
    abstract = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    shardings = ax.tree_shardings_matched(axes_tree, abstract, mesh, rules)
    flat_x, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(shardings)
    return jax.tree.unflatten(
        treedef, [jax.device_put(x, s) for x, s in zip(flat_x, flat_s)])
