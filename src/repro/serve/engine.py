"""Batched serving engine: continuous batching over fixed decode slots.

The serving shape the paper's throughput model reasons about: a prefill
phase (compute-bound) feeding fixed-width decode batches (HBM-bound).
Requests claim a free slot, are prefilled (right-aligned into the slot's
KV allocation), and the decode loop advances all live slots one token per
step; finished slots (EOS / max_new_tokens) free immediately — the
continuous-batching discipline of production LLM servers.

Single-host/CPU-runnable with smoke configs (tests, examples); on the
production mesh the same engine runs under pjit with the decode-shape
sharding rules from `repro.launch.dryrun.rules_for("decode_32k", ...)`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models.api import Model


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # [S] int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = field(default_factory=time.time)
    t_first: Optional[float] = None
    t_done: Optional[float] = None


class ServeEngine:
    def __init__(self, model: Model, params, batch_slots: int = 4,
                 max_seq: int = 128, prompt_len: int = 16):
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.B = batch_slots
        self.max_seq = max_seq
        # slots share a position counter, so prompts are padded/truncated
        # to a fixed prefill length (production engines use per-row
        # position vectors; the assigned decode shapes are uniform)
        self.prompt_len = prompt_len
        self.caches = model.init_caches(batch_slots, max_seq)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = np.zeros(batch_slots, np.int32)
        self._decode = jax.jit(model.decode_step)
        self._queue: List[Request] = []
        self.stats = {"prefills": 0, "decode_steps": 0, "tokens": 0}

    # --- admission ---
    def submit(self, req: Request):
        self._queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        """Prefill queued requests into free slots (batched per request;
        a production engine batches prefills too — chunked prefill)."""
        for slot in self._free_slots():
            if not self._queue:
                break
            req = self._queue.pop(0)
            S = self.prompt_len
            prompt = np.asarray(req.prompt, np.int32)[-S:]
            if len(prompt) < S:
                prompt = np.concatenate(
                    [np.zeros(S - len(prompt), np.int32), prompt])
            batch = {"tokens": jnp.asarray(prompt)[None]}
            logits, caches1 = self.model.prefill(self.params, batch,
                                                 self.max_seq)
            # copy the single-row prefill caches into this slot
            self.caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_index_in_dim(
                    full, one[:, 0].astype(full.dtype), slot,
                    1),
                self.caches, caches1)
            tok = int(jnp.argmax(logits[0]))
            req.output.append(tok)
            req.t_first = time.time()
            self.slot_req[slot] = req
            self.slot_pos[slot] = S
            self.stats["prefills"] += 1
            self.stats["tokens"] += S

    # --- decode ---
    def _live(self):
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    def step(self):
        """One engine step: admit, then decode all live slots one token."""
        self._admit()
        live = self._live()
        if not live:
            return False
        # all live slots share one position counter per slot: use max —
        # positions are per-slot via per-slot last tokens
        tokens = np.zeros((self.B, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.slot_req[i].output[-1]
        pos = int(self.slot_pos[live].max())
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tokens),
            jnp.asarray(pos, jnp.int32), self.caches)
        self.stats["decode_steps"] += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        for i in live:
            req = self.slot_req[i]
            req.output.append(int(nxt[i]))
            self.slot_pos[i] += 1
            self.stats["tokens"] += 1
            if (len(req.output) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt[i] == req.eos_id)
                    or self.slot_pos[i] >= self.max_seq - 1):
                req.done = True
                req.t_done = time.time()
                self.slot_req[i] = None
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (self._queue or self._live()) and steps < max_steps:
            self.step()
            steps += 1
        return steps

    def throughput_tokens_per_s(self, t0: float) -> float:
        return self.stats["tokens"] / max(time.time() - t0, 1e-9)
