"""Fault-tolerant checkpointing: async, atomic, elastic-restorable.

Design (1000+-node deployment):
* **Atomic commit** — a checkpoint directory is written under a temp name
  and renamed into place, then a `COMMIT` marker is fsynced; restore only
  considers committed checkpoints, so a node dying mid-save can never
  leave a half-checkpoint that gets loaded.
* **Async save** — the device→host snapshot is taken synchronously (cheap
  vs. a step), serialization runs on a background thread overlapped with
  training; `wait()` joins before the next save or shutdown.
* **Elastic restore** — the manifest stores the pytree structure + dtypes;
  restore re-places arrays under whatever mesh/shardings the *current*
  job provides (different device count than the writer = node-failure
  recovery / elastic rescale path).  On a multi-host fleet each host
  writes its addressable shards; this container has one host, so leaves
  are stored whole — the API is the same.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ChecksumError(RuntimeError):
    """A committed checkpoint's payload does not match its manifest
    checksum — a torn/corrupted write.  Callers treat the step as not
    done (recompute) rather than deserializing garbage."""

# numpy's npz cannot represent ml_dtypes (bfloat16, fp8): store raw bytes
# (uint8 view) and re-view on restore using the manifest dtype.
_RAW_DTYPES = {"bfloat16", "float8_e4m3fn", "float8_e5m2"}


def _encode(x: np.ndarray):
    if str(x.dtype) in _RAW_DTYPES:
        return x.view(np.uint8)
    return x


def _decode(x: np.ndarray, dtype_str: str):
    if dtype_str in _RAW_DTYPES:
        return x.view(np.dtype(getattr(jnp, dtype_str)))
    return x

COMMIT = "COMMIT"
MANIFEST = "manifest.json"
LEAVES = "leaves.npz"

# Manifest keys that may differ between two saves of identical state
# (wall clock, host identity).  They exist for humans and GC ordering
# only and MUST stay out of every fingerprint-covered byte: the payload
# checksum (`sha256`) hashes LEAVES alone, and `manifest_fingerprint`
# strips these keys, so resume identity never depends on *when* a
# checkpoint was written (tools/repro_lint rule RL201 polices new
# wall-clock reads in the deterministic core for the same reason).
VOLATILE_META = ("time",)


def manifest_fingerprint(meta: Dict[str, Any]) -> str:
    """sha256 over the manifest's deterministic content — everything
    except `VOLATILE_META` keys.  Two saves of bitwise-identical state
    produce the same fingerprint regardless of wall clock (regression:
    tests/test_reliability.py::test_fingerprints_time_independent)."""
    stable = {k: v for k, v in meta.items() if k not in VOLATILE_META}
    blob = json.dumps(stable, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------------- save ----------------
    def save(self, step: int, state: Any, blocking: bool = False):
        """Snapshot `state` (any pytree of arrays) at `step`."""
        self.wait()
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "n_leaves": len(host_leaves),
            "shapes": [list(x.shape) for x in host_leaves],
            "dtypes": [str(x.dtype) for x in host_leaves],
            "time": time.time(),
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            for p in (tmp, final):
                if os.path.exists(p):
                    shutil.rmtree(p)      # re-save of the same step
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, LEAVES),
                     **{f"leaf_{i}": _encode(x)
                        for i, x in enumerate(host_leaves)})
            # checksum the serialized payload so restore/load can tell a
            # torn write from a committed checkpoint
            meta["sha256"] = _sha256(os.path.join(tmp, LEAVES))
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(meta, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)        # atomic publish
            with open(os.path.join(final, COMMIT), "w") as f:
                f.write(str(meta["time"]))
                f.flush()
                os.fsync(f.fileno())
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        return step

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ---------------- restore ----------------
    def all_steps(self):
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, COMMIT))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _read(self, step: int, verify: bool):
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, MANIFEST)) as f:
            meta = json.load(f)
        leaves_path = os.path.join(path, LEAVES)
        # pre-checksum checkpoints (older writers) skip verification
        if verify and "sha256" in meta and _sha256(leaves_path) != meta["sha256"]:
            raise ChecksumError(
                f"checkpoint step {step} in {self.dir}: payload checksum "
                f"mismatch (torn write); treat as not done")
        return np.load(leaves_path), meta

    def load(self, step: Optional[int] = None,
             verify: bool = True) -> Tuple[List[np.ndarray], dict]:
        """Host-side read of a committed checkpoint: `(leaves, meta)` —
        the flat numpy leaf list plus the manifest — with no device
        placement and no target structure required (the resilient sweep
        path stores plain dict-of-array slabs).  `verify=True` checks
        the payload checksum and raises `ChecksumError` on mismatch."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        data, meta = self._read(step, verify)
        leaves = [_decode(data[f"leaf_{i}"], meta["dtypes"][i])
                  for i in range(meta["n_leaves"])]
        return leaves, meta

    def restore(self, target: Any, step: Optional[int] = None,
                shardings: Any = None, verify: bool = True):
        """Restore into the structure of `target` (a pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching pytree of
        shardings for elastic re-placement on the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        data, meta = self._read(step, verify)
        leaves, treedef = _flatten(target)
        if len(leaves) != len(data.files):
            raise ValueError(
                f"checkpoint has {len(data.files)} leaves, target expects "
                f"{len(leaves)} — structure mismatch")
        out = []
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = _decode(data[f"leaf_{i}"], meta["dtypes"][i])
            if hasattr(ref, "dtype") and arr.dtype != ref.dtype:
                arr = arr.astype(ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree.unflatten(treedef, out), step
