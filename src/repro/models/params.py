"""Single-source-of-truth parameter specs.

Every model builds a nested dict of `ParamDef`s (shape + logical axes +
initializer).  From one spec we derive: materialized parameters
(`init_params`), logical-axes trees (`axes_tree`) for GSPMD sharding,
`jax.eval_shape`-compatible abstract params for the dry-run
(`abstract_params`), and layer-stacked variants for `lax.scan`
(`stack_spec`).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: float | None = None    # default: 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Spec = Dict[str, Any]  # nested dict[str, ParamDef | Spec]


def _map_spec(spec: Spec, fn):
    return {k: (fn(v) if isinstance(v, ParamDef) else _map_spec(v, fn))
            for k, v in spec.items()}


def stack_spec(spec: Spec, n: int, axis_name: Optional[str] = None) -> Spec:
    """Prepend a stacked-layer dimension to every param (for lax.scan)."""
    return _map_spec(spec, lambda p: ParamDef(
        (n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale))


def axes_tree(spec: Spec):
    return _map_spec(spec, lambda p: p.axes)


def abstract_params(spec: Spec, dtype=jnp.bfloat16):
    return _map_spec(spec, lambda p: jax.ShapeDtypeStruct(p.shape, dtype))


def n_params(spec: Spec) -> int:
    total = 0
    for leaf in jax.tree.leaves(_map_spec(spec, lambda p: int(np.prod(p.shape)))):
        total += leaf
    return total


def init_params(spec: Spec, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        _map_spec(spec, lambda p: p), is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, max(len(leaves), 1))

    def make(p: ParamDef, k):
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else 1.0 / math.sqrt(fan_in)
        return (scale * jax.random.normal(k, p.shape, jnp.float32)).astype(dtype)

    return jax.tree.unflatten(treedef, [make(p, k) for p, k in zip(leaves, keys)])
