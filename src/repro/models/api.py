"""Unified model facade.

`Model(cfg)` exposes, for every family:
    spec / init / abstract_params / param_axes
    loss(params, batch)                           — training objective
    prefill(params, batch)  → (logits, caches)    — serving prompt phase
    decode_step(params, token, pos, caches)       — serving decode phase
    init_caches / cache_axes
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import encdec, lm
from .params import abstract_params, axes_tree, init_params, n_params


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.is_encdec = cfg.family == "audio"
        self.spec = (encdec.encdec_spec(cfg) if self.is_encdec
                     else lm.lm_spec(cfg))

    # --- parameters ---
    def init(self, key, dtype=jnp.bfloat16):
        return init_params(self.spec, key, dtype)

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract_params(self.spec, dtype)

    def param_axes(self):
        return axes_tree(self.spec)

    def n_params(self) -> int:
        return n_params(self.spec)

    # --- training ---
    def loss(self, params, batch):
        if self.is_encdec:
            return encdec.encdec_loss(self.cfg, params, batch)
        return lm.lm_loss(self.cfg, params, batch)

    # --- serving ---
    def prefill(self, params, batch, max_seq: int):
        cfg = self.cfg
        if self.is_encdec:
            return encdec.serve_prefill(cfg, params, batch["frames"],
                                        batch["tokens"])
        logits, caches, _ = lm.prefill(cfg, params, batch["tokens"], max_seq,
                                       batch.get("vision_embeds"))
        return logits, caches

    def decode_step(self, params, token, pos, caches):
        if self.is_encdec:
            return encdec.serve_decode_step(self.cfg, params, token, pos,
                                            caches)
        return lm.decode_step(self.cfg, params, token, pos, caches)

    def init_caches(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        if self.is_encdec:
            return encdec.init_dec_caches(self.cfg, batch, max_seq, dtype)
        return lm.init_caches(self.cfg, batch, max_seq, dtype)

    def cache_axes(self):
        if self.is_encdec:
            return encdec.dec_cache_axes(self.cfg)
        return lm.cache_axes(self.cfg)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
