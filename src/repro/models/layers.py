"""Shared layer primitives: norms, rotary embeddings (RoPE + M-RoPE),
MLP variants (SwiGLU / squared-ReLU / GELU), embeddings."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from .params import ParamDef, Spec


def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 1e6):
    """x: [B, S, H, hd]; positions: [B, S] (int)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, int, int],
                theta: float = 1e6):
    """Multimodal RoPE (Qwen2-VL): the rotary frequency bands are split
    into (temporal, height, width) sections, each rotated by its own
    position stream.  positions3: [3, B, S]."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # [hd/2]
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    sec = sec[: hd // 2]
    # select per-band position stream: [B, S, hd/2]
    p = jnp.moveaxis(positions3, 0, -1).astype(jnp.float32)  # [B,S,3]
    band_pos = jnp.take_along_axis(
        p, jnp.broadcast_to(sec[None, None, :], p.shape[:2] + sec.shape),
        axis=-1)                                        # [B,S,hd/2]
    angles = band_pos * freqs
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def mlp_spec(cfg: ArchConfig, d_ff: Optional[int] = None) -> Spec:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.act == "swiglu":
        return {
            "wi0": ParamDef((d, f), ("embed", "mlp")),
            "wi1": ParamDef((d, f), ("embed", "mlp")),
            "wo": ParamDef((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamDef((d, f), ("embed", "mlp")),
        "wo": ParamDef((f, d), ("mlp", "embed")),
    }


def mlp_apply(cfg: ArchConfig, p, x):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wi0"]) * (x @ p["wi1"])
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = shard(h, "batch", "seq", "mlp")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_spec(cfg: ArchConfig) -> Spec:
    d = cfg.d_model
    spec = {
        "tok": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=1.0),
        "final_norm": ParamDef((d,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        spec["head"] = ParamDef((d, cfg.vocab), ("embed", "vocab"))
    return spec


def embed_tokens(p, tokens):
    x = jnp.take(p["tok"], tokens, axis=0)
    return shard(x, "batch", "seq", "act_embed")


def unembed(cfg: ArchConfig, p, x, eps=1e-6):
    x = rms_norm(x, p["final_norm"], eps)
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return shard(logits, "batch", "seq", "vocab")


def chunked_ce(cfg: ArchConfig, p, hidden, labels, chunk: int = 512):
    """Cross-entropy without materializing [B,S,vocab] logits: logits are
    computed per sequence chunk inside a rematerialized scan (recomputed in
    the backward pass).  labels < 0 are masked.  Returns (nll_sum, count).
    """
    x = rms_norm(hidden, p["final_norm"], cfg.norm_eps)
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    B, S, d = x.shape
    c = max(1, min(chunk, S))
    if S % c:                      # pad to a chunk multiple (masked labels)
        pad = c - S % c
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        S += pad
    nc = S // c
    xc = jnp.moveaxis(x.reshape(B, nc, c, d), 1, 0)        # [nc,B,c,d]
    lc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)

    def body(carry, xs):
        nll_sum, cnt = carry
        xb, lb = xs
        logits = (xb @ w.astype(xb.dtype)).astype(jnp.float32)
        logits = shard(logits, "batch", "seq", "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)  # [B,c]
        safe = jnp.where(lb >= 0, lb, 0)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        valid = lb >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (nll_sum + nll.sum(), cnt + valid.sum()), None

    body = jax.checkpoint(body)
    (nll_sum, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc))
    return nll_sum, cnt
