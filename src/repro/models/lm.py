"""Decoder-only language models: dense, MoE, SSM (Mamba2), hybrid (Jamba),
and VLM backbones — one parameter spec + three entry points per model:
`forward_train`, `prefill`, `decode_step`.  Layers are stacked and scanned
(`lax.scan`) so HLO size is O(1) in depth; remat policy per block.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from . import attention as attn
from . import moe as moe_lib
from . import ssm as ssm_lib
from .layers import (chunked_ce, embed_spec, embed_tokens, mlp_apply,
                     mlp_spec, rms_norm, unembed)
from .params import ParamDef, Spec, stack_spec

# ---------------------------------------------------------------------------
# Block structure per family
# ---------------------------------------------------------------------------


def _layer_kinds(cfg: ArchConfig):
    """Per-layer (mixer, ffn) kinds for one period (hybrid) or the whole
    stack (homogeneous)."""
    if cfg.family == "ssm":
        return [("mamba", "none")]
    if cfg.family == "hybrid":
        kinds = []
        for i in range(cfg.attn_period):
            mixer = "attn" if i == cfg.attn_offset else "mamba"
            ffn = "moe" if (cfg.moe_period and i % cfg.moe_period == 1) else "mlp"
            kinds.append((mixer, ffn))
        return kinds
    ffn = "moe" if cfg.is_moe else "mlp"
    return [("attn", ffn)]


def block_spec(cfg: ArchConfig, mixer: str, ffn: str) -> Spec:
    d = cfg.d_model
    s: Spec = {"norm1": ParamDef((d,), ("embed",), init="ones")}
    s["mixer"] = attn.attn_spec(cfg) if mixer == "attn" else ssm_lib.ssm_spec(cfg)
    if ffn != "none":
        s["norm2"] = ParamDef((d,), ("embed",), init="ones")
        s["ffn"] = mlp_spec(cfg) if ffn == "mlp" else moe_lib.moe_spec(cfg)
    return s


def lm_spec(cfg: ArchConfig) -> Spec:
    spec: Spec = {"embed": embed_spec(cfg)}
    kinds = _layer_kinds(cfg)
    if len(kinds) == 1:
        spec["blocks"] = stack_spec(block_spec(cfg, *kinds[0]), cfg.n_layers,
                                    "layers")
    else:
        period = {f"sub{i}": block_spec(cfg, m, f)
                  for i, (m, f) in enumerate(kinds)}
        n_periods = cfg.n_layers // len(kinds)
        spec["blocks"] = stack_spec(period, n_periods, "layers")
    return spec


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_block(cfg: ArchConfig, mixer: str, ffn: str, p, x, *,
                 positions=None, positions3=None, cache=None,
                 mode: str = "train", pos=None):
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    new_cache = cache
    if mixer == "attn":
        if mode == "train":
            y = attn.attention(cfg, p["mixer"], h, positions, positions3)
        elif mode == "prefill":
            y, new_cache = attn.prefill_attention(cfg, p["mixer"], h,
                                                  positions, cache, positions3)
        else:
            y, new_cache = attn.decode_attention(cfg, p["mixer"], h, pos,
                                                 cache, positions3)
    else:
        if mode == "decode":
            y, new_cache = ssm_lib.ssm_decode_step(cfg, p["mixer"], h, cache)
        else:
            y, new_cache = ssm_lib.ssm_apply(cfg, p["mixer"], h, cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if ffn == "mlp":
            y = mlp_apply(cfg, p["ffn"], h)
        else:
            y, aux = moe_lib.moe_apply(cfg, p["ffn"], h)
        x = x + y
    return x, new_cache, aux


def _remat(cfg: ArchConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


def _scan_stack(cfg: ArchConfig, blocks_p, x, per_layer_fn, caches=None):
    """Scan over the stacked layer dim.  `per_layer_fn(x, p_l, cache_l) →
    (x, new_cache_l, aux)`.  Returns (x, new_caches, aux_sum)."""
    kinds = _layer_kinds(cfg)

    if caches is None:
        def body_nc(carry, p_l):
            xcur, aux_acc = carry
            xcur, _, aux = per_layer_fn(xcur, p_l, None)
            return (xcur, aux_acc + aux), None

        body_nc = _remat(cfg, body_nc)
        (x, aux), _ = jax.lax.scan(
            body_nc, (x, jnp.zeros((), jnp.float32)), blocks_p)
        return x, None, aux

    def body(carry, xs):
        xcur, aux_acc = carry
        p_l, cache_l = xs
        xcur, new_cache, aux = per_layer_fn(xcur, p_l, cache_l)
        return (xcur, aux_acc + aux), new_cache

    body = _remat(cfg, body)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks_p, caches))
    return x, new_caches, aux


def _per_layer(cfg: ArchConfig, mode, positions=None, positions3=None,
               pos=None):
    kinds = _layer_kinds(cfg)

    def fn(x, p_l, cache_l):
        if len(kinds) == 1:
            mixer, ffn = kinds[0]
            return _apply_block(cfg, mixer, ffn, p_l, x, positions=positions,
                                positions3=positions3, cache=cache_l,
                                mode=mode, pos=pos)
        # hybrid period: unrolled sub-layers
        aux_t = jnp.zeros((), jnp.float32)
        new_caches = {}
        for i, (mixer, ffn) in enumerate(kinds):
            sub = f"sub{i}"
            c = cache_l[sub] if cache_l is not None else None
            x, nc, aux = _apply_block(cfg, mixer, ffn, p_l[sub], x,
                                      positions=positions,
                                      positions3=positions3, cache=c,
                                      mode=mode, pos=pos)
            new_caches[sub] = nc
            aux_t = aux_t + aux
        return x, (new_caches if cache_l is not None else None), aux_t

    return fn


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16):
    """Stacked per-layer caches matching the scan layout."""
    kinds = _layer_kinds(cfg)

    def one(mixer):
        if mixer == "attn":
            return attn.init_cache(cfg, batch, max_seq, dtype)
        return ssm_lib.init_ssm_cache(cfg, batch, dtype)

    def stack(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape),
                            tree)

    if len(kinds) == 1:
        return stack(one(kinds[0][0]), cfg.n_layers)
    period = {f"sub{i}": one(m) for i, (m, _) in enumerate(kinds)}
    return stack(period, cfg.n_layers // len(kinds))


def cache_axes(cfg: ArchConfig):
    """Logical axes tree mirroring `init_caches` output."""
    kinds = _layer_kinds(cfg)

    def one(mixer):
        if mixer == "attn":
            return attn.KVCache(
                ("layers", "batch", "seq_kv", "kv_heads", "head_dim"),
                ("layers", "batch", "seq_kv", "kv_heads", "head_dim"))
        return ssm_lib.SSMCache(
            ("layers", "batch", "conv", None),
            ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"))

    if len(kinds) == 1:
        return one(kinds[0][0])
    return {f"sub{i}": one(m) for i, (m, _) in enumerate(kinds)}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def _positions3_default(positions):
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def forward_hidden(cfg: ArchConfig, params, tokens, extra_embeds=None):
    """tokens [B,S] (inputs); extra_embeds [B,Sv,d] optional multimodal
    prefix.  Returns (hidden [B,S_total,d], aux_loss)."""
    x = embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    positions3 = (_positions3_default(positions)
                  if cfg.mrope_sections is not None else None)
    x = shard(x, "batch", "seq", "act_embed")
    fn = _per_layer(cfg, "train", positions, positions3)
    x, _, aux = _scan_stack(cfg, params["blocks"], x, fn)
    return x, aux


def forward_train(cfg: ArchConfig, params, tokens, extra_embeds=None):
    """Full-logits variant (tests / small models)."""
    x, aux = forward_hidden(cfg, params, tokens, extra_embeds)
    return unembed(cfg, params["embed"], x, cfg.norm_eps), aux


def lm_loss(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Dict]:
    """Causal LM loss via chunked CE (never materializes full logits).
    batch: {"tokens": [B,S]} (+ "vision_embeds").

    Inputs keep the full length S (last position's label is masked) rather
    than slicing to S−1: power-of-two sequence lengths keep every chunked
    path (CE, SSD, blockwise attention) exactly divisible and keep the
    sequence shardable (EXPERIMENTS §Perf)."""
    tokens = batch["tokens"]
    inputs = tokens
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
    extra = batch.get("vision_embeds")
    hidden, aux = forward_hidden(cfg, params, inputs, extra)
    if extra is not None:
        # no loss on the multimodal prefix
        pad = jnp.full((labels.shape[0], extra.shape[1]), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    nll_sum, cnt = chunked_ce(cfg, params["embed"], hidden, labels)
    denom = jnp.maximum(cnt, 1)
    loss = nll_sum / denom
    total = loss + cfg.router_aux_coef * aux
    return total, {"loss": loss, "aux_loss": aux,
                   "tokens": denom.astype(jnp.float32)}


def prefill(cfg: ArchConfig, params, tokens, max_seq: int,
            extra_embeds=None, caches=None):
    """Prompt processing; writes K/V (or SSM state) caches.
    Returns (logits_last [B,vocab], caches, seq_len)."""
    x = embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    positions3 = (_positions3_default(positions)
                  if cfg.mrope_sections is not None else None)
    if caches is None:
        caches = init_caches(cfg, B, max_seq)
    fn = _per_layer(cfg, "prefill", positions, positions3)
    x, caches, _ = _scan_stack(cfg, params["blocks"], x, fn, caches)
    logits = unembed(cfg, params["embed"], x[:, -1:], cfg.norm_eps)
    return logits[:, 0], caches, S


def decode_step(cfg: ArchConfig, params, token, pos, caches):
    """One decode step.  token [B,1] int32; pos [] int32 (current index).
    Returns (logits [B,vocab], new_caches)."""
    x = embed_tokens(params["embed"], token)
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    positions3 = (_positions3_default(positions)
                  if cfg.mrope_sections is not None else None)
    fn = _per_layer(cfg, "decode", positions, positions3, pos=pos)
    x, caches, _ = _scan_stack(cfg, params["blocks"], x, fn, caches)
    logits = unembed(cfg, params["embed"], x, cfg.norm_eps)
    return logits[:, 0], caches
