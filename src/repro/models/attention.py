"""Grouped-query attention: training/prefill (full causal), decode with a
KV cache, and optional cross-attention (enc-dec).

The default math path is pure jnp (the oracle the Pallas flash-attention
kernel is validated against); `cfg.use_flash_kernel` switches prefill to
`repro.kernels.flash_attention` on TPU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from .layers import apply_mrope, apply_rope, rms_norm
from .params import ParamDef, Spec

NEG_INF = -2.0e38


def attn_spec(cfg: ArchConfig, cross: bool = False) -> Spec:
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    spec = {
        "q": ParamDef((d, H, hd), ("embed", "heads", "head_dim")),
        "k": ParamDef((d, Hk, hd), ("embed", "kv_heads", "head_dim")),
        "v": ParamDef((d, Hk, hd), ("embed", "kv_heads", "head_dim")),
        "o": ParamDef((H, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        spec["q_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
        spec["k_norm"] = ParamDef((hd,), ("head_dim",), init="ones")
    return spec


def _project_qkv(cfg: ArchConfig, p, x, x_kv=None, positions=None,
                 positions3=None, use_rope=True):
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["k"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["v"].astype(x.dtype))
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and positions is not None:
        if cfg.mrope_sections is not None and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", "head_dim")
    k = shard(k, "batch", "seq_kv", "kv_heads", "head_dim")
    v = shard(v, "batch", "seq_kv", "kv_heads", "head_dim")
    return q, k, v


def _sdpa(cfg: ArchConfig, q, k, v, mask):
    """q: [B,Sq,H,hd]; k,v: [B,Skv,Hk,hd]; mask broadcastable to
    [B,1,Sq,Skv] (True = attend)."""
    B, Sq, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Sq, Hk, G, hd)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(hd).astype(jnp.float32)
    if cfg.attn_logits_soft_cap:
        c = cfg.attn_logits_soft_cap
        logits = c * jnp.tanh(logits / c)
    logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", w, v)
    return out.reshape(B, Sq, H, hd)


# Use blockwise (online-softmax) attention above this many score elements.
_BLOCKWISE_THRESHOLD = 4096 * 4096


def _sdpa_blockwise(cfg: ArchConfig, q, k, v, causal: bool,
                    q_chunk: int = 512, kv_chunk: int = 1024):
    """Flash-style double-blocked attention (jnp): scans query blocks, and
    for each, key/value blocks with an online-softmax carry.  Never
    materializes [Sq,Skv] scores — this is the memory-sane path for 32k+
    sequences and the oracle shape of the Pallas kernel."""
    B, Sq0, H, hd = q.shape
    Skv0 = k.shape[1]
    Hk = k.shape[2]
    G = H // Hk
    qc = max(1, min(q_chunk, Sq0))
    kc = max(1, min(kv_chunk, Skv0))
    # pad instead of shrinking blocks (non-divisible S must not degenerate
    # the chunk size); padded KV columns are masked via kv_len below.
    qpad, kpad = (-Sq0) % qc, (-Skv0) % kc
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
    Sq, Skv = Sq0 + qpad, Skv0 + kpad
    nq, nk = Sq // qc, Skv // kc
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qb = jnp.moveaxis(q.reshape(B, nq, qc, Hk, G, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nk, kc, Hk, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, kc, Hk, hd), 1, 0)

    def q_block(_, qx):
        qi, qblk = qx                                     # [], [B,qc,Hk,G,hd]
        q_pos = qi * qc + jnp.arange(qc)

        def kv_block(carry, kx):
            m, l, acc = carry
            ki, kblk, vblk = kx
            s = jnp.einsum("bqhgk,bshk->bhgqs", qblk, kblk)
            s = s.astype(jnp.float32) * scale             # [B,Hk,G,qc,kc]
            if cfg.attn_logits_soft_cap:
                c = cfg.attn_logits_soft_cap
                s = c * jnp.tanh(s / c)
            k_pos = ki * kc + jnp.arange(kc)
            mask = k_pos[None, :] < Skv0                  # padded KV cols
            if causal:
                mask = mask & (q_pos[:, None] >= k_pos[None, :])
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))             # [B,Hk,G,qc]
            corr = jnp.exp(m - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l_new = l * corr + p_.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqs,bshk->bhgqk", p_, vblk.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, qc), jnp.float32)
        a0 = jnp.zeros((B, Hk, G, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0), (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l[..., None], 1e-30)      # [B,Hk,G,qc,hd]
        out = jnp.moveaxis(out, 3, 1).reshape(B, qc, H, hd)
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_block, None, (jnp.arange(nq), qb))
    return jnp.moveaxis(blocks, 0, 1).reshape(B, Sq, H, hd)[:, :Sq0]


def _dispatch_sdpa(cfg: ArchConfig, q, k, v, causal: bool, mask=None):
    """Pick the O(S²)-mask path (small) or blockwise path (large)."""
    Sq, Skv = q.shape[1], k.shape[1]
    if Sq * Skv >= _BLOCKWISE_THRESHOLD and mask is None:
        return _sdpa_blockwise(cfg, q, k, v, causal)
    if mask is None:
        if causal:
            qp, kp = jnp.arange(Sq), jnp.arange(Skv)
            mask = (qp[:, None] >= kp[None, :])[None, None]
        else:
            mask = jnp.ones((1, 1, Sq, Skv), bool)
    return _sdpa(cfg, q, k, v, mask)


def attention(cfg: ArchConfig, p, x, positions, positions3=None,
              causal=True, x_kv=None, kv_positions=None, use_rope=True):
    """Full attention for training / prefill / encoder / cross-attn."""
    q, k, v = _project_qkv(cfg, p, x, x_kv, positions, positions3, use_rope)
    if cfg.use_flash_kernel and causal and x_kv is None:
        from ..kernels.flash_attention import ops as fa
        out = fa.flash_attention(q, k, v, causal=True)
    else:
        out = _dispatch_sdpa(cfg, q, k, v, causal)
    out = shard(out, "batch", "seq", "heads", "head_dim")
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"].astype(out.dtype))
    return shard(y, "batch", "seq", "act_embed")


class KVCache(NamedTuple):
    k: jax.Array       # [B, Smax, Hk, hd]
    v: jax.Array


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def prefill_attention(cfg: ArchConfig, p, x, positions, cache: KVCache,
                      positions3=None):
    """Causal attention that also writes the prompt K/V into the cache."""
    q, k, v = _project_qkv(cfg, p, x, None, positions, positions3)
    S = x.shape[1]
    cache = KVCache(jax.lax.dynamic_update_slice(
                        cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0)),
                    jax.lax.dynamic_update_slice(
                        cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0)))
    out = _dispatch_sdpa(cfg, q, k, v, causal=True)
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"].astype(out.dtype))
    return shard(y, "batch", "seq", "act_embed"), cache


def decode_attention(cfg: ArchConfig, p, x, pos, cache: KVCache,
                     positions3=None):
    """One-token decode: x [B,1,d]; pos [] scalar current index (same for
    all batch rows).  Returns (y [B,1,d], cache')."""
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(cfg, p, x, None, positions, positions3)
    cache = KVCache(
        jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                     (0, pos, 0, 0)),
        jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                     (0, pos, 0, 0)))
    Smax = cache.k.shape[1]
    mask = (jnp.arange(Smax)[None, None, :] <= pos)[:, None]   # [1,1,1,Smax]
    out = _sdpa(cfg, q, cache.k, cache.v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"].astype(out.dtype))
    return shard(y, "batch", "seq", "act_embed"), cache


def cross_attention_cached(cfg: ArchConfig, p, x, enc_k, enc_v):
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["q"].astype(x.dtype))
    out = _dispatch_sdpa(cfg, q, enc_k, enc_v, causal=False)
    y = jnp.einsum("bshk,hkd->bsd", out, p["o"].astype(out.dtype))
    return y
