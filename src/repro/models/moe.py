"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Dispatch: capacity-bucketed one-hot einsum — MXU-friendly and
GSPMD-shardable (experts on the `model`/EP axis); GSPMD lowers the
sharded dispatch/combine contractions to the EP all-to-all pattern.  The
grouping is sequence-aligned so capacity bucketing never crosses the
batch sharding (see `moe_apply` and EXPERIMENTS §Perf).  The router has a
fused Pallas kernel (`repro.kernels.moe_gating`).

Router: softmax over experts, top-k, renormalized; load-balancing aux loss
(Switch-style) returned alongside.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from .params import ParamDef, Spec


def moe_spec(cfg: ArchConfig, d_ff: int | None = None) -> Spec:
    d, f, E = cfg.d_model, d_ff or cfg.d_ff, cfg.n_experts
    spec = {"router": ParamDef((d, E), ("embed", "expert"))}
    if cfg.act == "swiglu":
        spec.update({
            "wi0": ParamDef((E, d, f), ("expert", "embed", "mlp")),
            "wi1": ParamDef((E, d, f), ("expert", "embed", "mlp")),
            "wo": ParamDef((E, f, d), ("expert", "mlp", "embed")),
        })
    else:
        spec.update({
            "wi": ParamDef((E, d, f), ("expert", "embed", "mlp")),
            "wo": ParamDef((E, f, d), ("expert", "mlp", "embed")),
        })
    return spec


def router_topk(cfg: ArchConfig, p, x) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gate_weights [N,k], expert_idx [N,k], aux_loss []).
    x: [N, d] flattened tokens."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [N,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E · Σ_e f_e · P_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(
        jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    aux = E * jnp.sum(me * ce)
    return gate.astype(x.dtype), idx, aux


def moe_apply(cfg: ArchConfig, p, x, group_size: int = 512
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] → (y, aux_loss).

    Group-local dense dispatch with **sequence-aligned groups**: each
    group is a chunk of one batch row, so groups never straddle the batch
    sharding — fixed token-block groups did, and GSPMD answered with
    full-batch all-gathers of the activations per MoE layer plus 16×
    redundant dispatch compute (EXPERIMENTS §Perf, moonshot iterations).
    Groups also stay small (`group_size`): the one-hot dispatch matmul
    costs 2·cf·ng·k·d FLOPs/token — linear in group size — so per-sequence
    groups (ng=S) made dispatch dominate expert FFN compute.  The position
    cumsum is group-local (no cross-device dependency).  Sequences are
    padded to a group multiple; padded tokens get gate=0 and are never
    dispatched.
    """
    B, S0, d = x.shape
    ng = max(1, min(group_size, S0))
    pad = (-S0) % ng
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    N = B * S
    G = N // ng
    xg = x.reshape(G, ng, d)

    gate, idx, aux = router_topk(cfg, p, xg.reshape(N, d))
    if pad:
        live = (jnp.arange(S) < S0)
        gate = gate * jnp.broadcast_to(
            live[None, :, None], (B, S, gate.shape[-1])).reshape(N, -1)
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(cfg.capacity_factor * ng * k / E))
    gate = gate.reshape(G, ng, k)
    idx = idx.reshape(G, ng, k)

    onehot = jax.nn.one_hot(idx, E, dtype=x.dtype)              # [G,n,k,E]
    # slot of each (token, choice) within its expert's group-local buffer
    pos = jnp.cumsum(onehot.reshape(G, ng * k, E), axis=1) - 1.0
    pos = (pos.reshape(G, ng, k, E) * onehot).sum(-1)           # [G,n,k]
    keep = (pos < C) & (gate > 0)
    pos = jnp.where(keep, pos, 0).astype(jnp.int32)

    pos_oh = jax.nn.one_hot(pos, C, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    dispatch = jnp.einsum("gnke,gnkc->gnec", onehot, pos_oh)    # [G,n,E,C]
    dispatch = shard(dispatch, "batch", None, "expert", "expert_cap")
    expert_in = jnp.einsum("gnd,gnec->gecd", xg, dispatch)      # [G,E,C,d]
    expert_in = shard(expert_in, "batch", "expert", "expert_cap", "embed")

    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["wi0"])) * \
            jnp.einsum("gecd,edf->gecf", expert_in, p["wi1"])
    elif cfg.act == "sq_relu":
        h = jnp.square(jax.nn.relu(
            jnp.einsum("gecd,edf->gecf", expert_in, p["wi"])))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", expert_in, p["wi"]))
    h = shard(h, "batch", "expert", "expert_cap", "mlp")
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["wo"])       # [G,E,C,d]

    combine = dispatch * jnp.einsum("gnk,gnke->gne", gate, onehot)[..., None]
    y = jnp.einsum("gecd,gnec->gnd", expert_out, combine)
    # constrain the combine output back to the sharded residual layout so
    # the EP-boundary reduction lowers as reduce-scatter, not all-reduce
    y = shard(y.reshape(B, S, d), "batch", "seq", "act_embed")
    return y[:, :S0], aux
