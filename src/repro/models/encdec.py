"""Encoder-decoder transformer (Whisper-style audio backbone).

The conv frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings [B, S_enc, d_model] (post-conv features);
sinusoidal positions are added here.  Decoder: causal self-attention
(cached) + cross-attention over encoder states + MLP.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from . import attention as attn
from .layers import mlp_apply, mlp_spec, rms_norm
from .params import ParamDef, Spec, stack_spec


def _sinusoid(S, d):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def enc_block_spec(cfg: ArchConfig) -> Spec:
    d = cfg.d_model
    return {
        "norm1": ParamDef((d,), ("embed",), init="ones"),
        "mixer": attn.attn_spec(cfg),
        "norm2": ParamDef((d,), ("embed",), init="ones"),
        "ffn": mlp_spec(cfg),
    }


def dec_block_spec(cfg: ArchConfig) -> Spec:
    d = cfg.d_model
    return {
        "norm1": ParamDef((d,), ("embed",), init="ones"),
        "self": attn.attn_spec(cfg),
        "norm_x": ParamDef((d,), ("embed",), init="ones"),
        "cross": attn.attn_spec(cfg, cross=True),
        "norm2": ParamDef((d,), ("embed",), init="ones"),
        "ffn": mlp_spec(cfg),
    }


def encdec_spec(cfg: ArchConfig) -> Spec:
    d = cfg.d_model
    return {
        "embed": {
            "tok": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=1.0),
            "final_norm": ParamDef((d,), ("embed",), init="ones"),
            "head": ParamDef((d, cfg.vocab), ("embed", "vocab")),
        },
        "encoder": stack_spec(enc_block_spec(cfg), cfg.n_enc_layers, "layers"),
        "enc_norm": ParamDef((d,), ("embed",), init="ones"),
        "decoder": stack_spec(dec_block_spec(cfg), cfg.n_layers, "layers"),
    }


def encode(cfg: ArchConfig, params, frames):
    """frames: [B, S_enc, d] precomputed frame embeddings (frontend stub)."""
    B, S, d = frames.shape
    x = frames + _sinusoid(S, d).astype(frames.dtype)[None]
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(xcur, p):
        h = rms_norm(xcur, p["norm1"], cfg.norm_eps)
        y = attn.attention(cfg, p["mixer"], h, positions, causal=False,
                           use_rope=False)
        xcur = xcur + y
        h = rms_norm(xcur, p["norm2"], cfg.norm_eps)
        return xcur + mlp_apply(cfg, p["ffn"], h), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(cfg: ArchConfig, p_cross, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["k"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p_cross["v"].astype(enc_out.dtype))
    return k, v


class DecCache(NamedTuple):
    self_kv: attn.KVCache          # stacked [L, ...]
    cross_k: jax.Array             # [L, B, S_enc, Hk, hd]
    cross_v: jax.Array


def precompute_cross(cfg: ArchConfig, params, enc_out):
    def body(_, p):
        k, v = _cross_kv(cfg, p["cross"], enc_out)
        return None, (k, v)
    _, (ks, vs) = jax.lax.scan(body, None, params["decoder"])
    return ks, vs


def decode_train(cfg: ArchConfig, params, tokens, enc_out):
    """Teacher-forced decoder pass: tokens [B,S_dec]."""
    x = jnp.take(params["embed"]["tok"], tokens, axis=0)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(xcur, p):
        h = rms_norm(xcur, p["norm1"], cfg.norm_eps)
        y = attn.attention(cfg, p["self"], h, positions)
        xcur = xcur + y
        h = rms_norm(xcur, p["norm_x"], cfg.norm_eps)
        k, v = _cross_kv(cfg, p["cross"], enc_out)
        xcur = xcur + attn.cross_attention_cached(cfg, p["cross"], h, k, v)
        h = rms_norm(xcur, p["norm2"], cfg.norm_eps)
        return xcur + mlp_apply(cfg, p["ffn"], h), None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["decoder"])
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    return (x @ params["embed"]["head"].astype(x.dtype)).astype(jnp.float32)


def encdec_loss(cfg: ArchConfig, params, batch) -> Tuple[jax.Array, Dict]:
    """batch: {"frames": [B,S_enc,d], "tokens": [B,S_dec]}"""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    inputs, labels = tokens[:, :-1], tokens[:, 1:]
    logits = decode_train(cfg, params, inputs, enc_out)
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels >= 0
    safe = jnp.where(valid, labels, 0)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(valid.sum(), 1)
    loss = jnp.where(valid, nll, 0.0).sum() / denom
    return loss, {"loss": loss, "aux_loss": jnp.zeros(()),
                  "tokens": denom.astype(jnp.float32)}


def init_dec_caches(cfg: ArchConfig, batch: int, enc_seq: int,
                    dtype=jnp.bfloat16) -> DecCache:
    L = cfg.n_layers
    kv = attn.init_cache(cfg, batch, cfg.dec_max_seq, dtype)
    stacked = attn.KVCache(
        jnp.broadcast_to(kv.k[None], (L,) + kv.k.shape),
        jnp.broadcast_to(kv.v[None], (L,) + kv.v.shape))
    ck = jnp.zeros((L, batch, enc_seq, cfg.n_kv_heads, cfg.hd), dtype)
    return DecCache(stacked, ck, jnp.zeros_like(ck))


def dec_cache_axes(cfg: ArchConfig) -> DecCache:
    kv = attn.KVCache(("layers", "batch", "seq", "kv_heads", "head_dim"),
                      ("layers", "batch", "seq", "kv_heads", "head_dim"))
    cx = ("layers", "batch", "seq_kv", "kv_heads", "head_dim")
    return DecCache(kv, cx, cx)


def serve_prefill(cfg: ArchConfig, params, frames, prompt):
    """Encode audio + prefill decoder prompt.  Returns (logits, DecCache)."""
    enc_out = encode(cfg, params, frames)
    B = frames.shape[0]
    cross_k, cross_v = precompute_cross(cfg, params, enc_out)
    caches = init_dec_caches(cfg, B, frames.shape[1], frames.dtype)
    caches = DecCache(caches.self_kv, cross_k.astype(frames.dtype),
                      cross_v.astype(frames.dtype))

    S = prompt.shape[1]
    x = jnp.take(params["embed"]["tok"], prompt, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(xcur, xs):
        p, kv, ck, cv = xs
        h = rms_norm(xcur, p["norm1"], cfg.norm_eps)
        y, kv = attn.prefill_attention(cfg, p["self"], h, positions, kv)
        xcur = xcur + y
        h = rms_norm(xcur, p["norm_x"], cfg.norm_eps)
        xcur = xcur + attn.cross_attention_cached(cfg, p["cross"], h, ck, cv)
        h = rms_norm(xcur, p["norm2"], cfg.norm_eps)
        return xcur + mlp_apply(cfg, p["ffn"], h), kv

    x, self_kv = jax.lax.scan(
        body, x, (params["decoder"], caches.self_kv, caches.cross_k,
                  caches.cross_v))
    x = rms_norm(x[:, -1:], params["embed"]["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"]["head"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], DecCache(self_kv, caches.cross_k, caches.cross_v)


def serve_decode_step(cfg: ArchConfig, params, token, pos, caches: DecCache):
    """One decoder token with self-KV update + cross-attention over the
    (fixed) encoder cache."""
    x = jnp.take(params["embed"]["tok"], token, axis=0)

    def body(xcur, xs):
        p, kv, ck, cv = xs
        h = rms_norm(xcur, p["norm1"], cfg.norm_eps)
        y, kv = attn.decode_attention(cfg, p["self"], h, pos, kv)
        xcur = xcur + y
        h = rms_norm(xcur, p["norm_x"], cfg.norm_eps)
        xcur = xcur + attn.cross_attention_cached(cfg, p["cross"], h, ck, cv)
        h = rms_norm(xcur, p["norm2"], cfg.norm_eps)
        return xcur + mlp_apply(cfg, p["ffn"], h), kv

    x, self_kv = jax.lax.scan(
        body, x, (params["decoder"], caches.self_kv, caches.cross_k,
                  caches.cross_v))
    x = rms_norm(x, params["embed"]["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"]["head"].astype(x.dtype)).astype(jnp.float32)
    return logits[:, 0], DecCache(self_kv, caches.cross_k, caches.cross_v)
