"""Mamba2 mixer via SSD — state-space duality (arXiv:2405.21060).

TPU adaptation (DESIGN.md §3): the chunked SSD decomposition maps the
intra-chunk work onto dense MXU matmuls and carries inter-chunk state with
a cheap `lax.scan` — no warp-level primitives needed.  The intra-chunk
core has a Pallas kernel (`repro.kernels.ssd_scan`) validated against this
pure-jnp implementation.

Layout: d_inner = expand·d_model = n_heads·head_dim; single B/C group
(shared across heads, Mamba2 default).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.axes import shard
from .layers import rms_norm
from .params import ParamDef, Spec


def ssm_spec(cfg: ArchConfig) -> Spec:
    d, di, st, nh, K = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                        cfg.ssm_heads, cfg.ssm_conv)
    return {
        "in_z": ParamDef((d, di), ("embed", "ssm_inner")),
        "in_x": ParamDef((d, di), ("embed", "ssm_inner")),
        "in_b": ParamDef((d, st), ("embed", "ssm_state")),
        "in_c": ParamDef((d, st), ("embed", "ssm_state")),
        "in_dt": ParamDef((d, nh), ("embed", "ssm_heads")),
        "conv_x": ParamDef((K, di), ("conv", "ssm_inner"), scale=0.5),
        "conv_b": ParamDef((K, st), ("conv", "ssm_state"), scale=0.5),
        "conv_c": ParamDef((K, st), ("conv", "ssm_state"), scale=0.5),
        "a_log": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "gate_norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv: x [B,S,F], w [K,F].  If `state` [B,K-1,F] is
    given (decode), convolves the concatenation and returns new state."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros_like(x[:, : K - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):]
    return jax.nn.silu(out), new_state


def _ssd_chunked(xdt, log_a, b, c, chunk: int):
    """Chunked SSD scan.

    xdt: [B,S,nh,hd] (dt-scaled inputs);  log_a: [B,S,nh] (per-step log
    decay);  b, c: [B,S,st].  Returns y [B,S,nh,hd].
    """
    B, S0, nh, hd = xdt.shape
    st = b.shape[-1]
    Q = min(chunk, S0)
    pad = (-S0) % Q
    if pad:
        # pad with identity steps (xdt=0, log_a=0) instead of shrinking Q —
        # a non-divisible S must NOT degenerate the chunk size (Q=1 turns
        # the chunked algorithm into a per-token scan; EXPERIMENTS §Perf).
        zf = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        xdt, log_a, b, c = zf(xdt), zf(log_a), zf(b), zf(c)
    S = S0 + pad
    nC = S // Q
    rs = lambda t: t.reshape((B, nC, Q) + t.shape[2:])
    xdt, log_a, b, c = rs(xdt), rs(log_a), rs(b), rs(c)

    acum = jnp.cumsum(log_a, axis=2)                       # [B,nC,Q,nh]
    # intra-chunk (dense, MXU): Y[q] = Σ_{k≤q} (C_q·B_k) e^{A_q−A_k} xdt[k]
    # NOTE: built as 2-operand contractions only — 3-operand einsums here
    # lower to rank-6 broadcast products ([B,nC,Q,Q,nh,hd]!) instead of
    # batched matmuls (observed via the dry-run roofline; see EXPERIMENTS
    # §Perf mamba2 iteration 0).
    s_qk = jnp.einsum("bnqs,bnks->bnqk", c, b)             # [B,nC,Q,Q]
    gap = acum[:, :, :, None, :] - acum[:, :, None, :, :]  # [B,nC,Q,Q,nh]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask BEFORE exp: the future-side gap is large-positive, and
    # where(mask, exp(gap), 0) still differentiates through an inf → NaN
    gap = jnp.where(causal[None, None, :, :, None], gap, -1e9)
    decay = jnp.exp(gap)
    w = s_qk[:, :, :, :, None].astype(jnp.float32) * decay  # [B,nC,Q,Q,nh]
    y_intra = jnp.einsum("bnqkh,bnkhd->bnqhd", w,
                         xdt.astype(jnp.float32))

    # chunk summaries: H_n = Σ_k e^{A_Q−A_k} B_k ⊗ xdt_k   [B,nC,nh,hd,st]
    tail = jnp.exp(acum[:, :, -1:, :] - acum)              # [B,nC,Q,nh]
    xtail = xdt.astype(jnp.float32) * tail[..., None]      # [B,nC,Q,nh,hd]
    h_chunk = jnp.einsum("bnqhd,bnqs->bnhds", xtail,
                         b.astype(jnp.float32))
    a_chunk = jnp.exp(acum[:, :, -1, :])                   # [B,nC,nh]

    # inter-chunk recurrence (cheap scan over nC chunks)
    def step(h, inp):
        hc, ac = inp
        h_new = h * ac[..., None, None] + hc
        return h_new, h
    h0 = jnp.zeros((B, nh, hd, st), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(h_chunk, 1, 0), jnp.moveaxis(a_chunk, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # [B,nC,nh,hd,st]

    y_inter = jnp.einsum("bnqs,bnhds->bnqhd",
                         c.astype(jnp.float32), h_prevs) * \
        jnp.exp(acum)[..., None]
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    return y[:, :S0]


class SSMCache(NamedTuple):
    conv: jax.Array    # [B, K-1, di + 2·st]
    h: jax.Array       # [B, nh, hd, st] (f32)


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> SSMCache:
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    return SSMCache(
        jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * st), dtype),
        jnp.zeros((batch, nh, hd, st), jnp.float32))


def _project(cfg: ArchConfig, p, x):
    z = x @ p["in_z"]
    xs = x @ p["in_x"]
    b = x @ p["in_b"]
    c = x @ p["in_c"]
    dt = jax.nn.softplus((x @ p["in_dt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xs, b, c, dt


def ssm_apply(cfg: ArchConfig, p, x, cache: SSMCache | None = None):
    """Full-sequence Mamba2 mixer.  x: [B,S,d] → (y, new_cache or None)."""
    B, S, d = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xs, b, c, dt = _project(cfg, p, x)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], -1)
    feats = jnp.concatenate([xs, b, c], -1)
    feats, conv_state = _causal_conv(feats, conv_w,
                                     cache.conv if cache is not None else None)
    xs, b, c = jnp.split(feats, [di, di + st], axis=-1)
    xs = shard(xs, "batch", "seq", "ssm_inner")

    a = -jnp.exp(p["a_log"].astype(jnp.float32))           # [nh]
    log_a = dt * a                                         # [B,S,nh]
    xh = xs.reshape(B, S, nh, hd)
    xdt = xh * dt[..., None].astype(xh.dtype)

    if cfg.use_flash_kernel:
        from ..kernels.ssd_scan import ops as ssd
        y = ssd.ssd_scan(xdt, log_a, b, c, chunk=cfg.ssm_chunk)
    else:
        y = _ssd_chunked(xdt, log_a, b, c, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = shard(y, "batch", "seq", "ssm_inner")

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = shard(y @ p["out"], "batch", "seq", "act_embed")
    new_cache = None
    if cache is not None:
        # final ssm state for decode handoff
        h = _final_state(xdt, log_a, b)
        new_cache = SSMCache(conv_state.astype(cache.conv.dtype), h)
    return out, new_cache


def _final_state(xdt, log_a, b):
    """h_S = Σ_k e^{A_S−A_k} B_k ⊗ xdt_k   (f32, [B,nh,hd,st])."""
    acum = jnp.cumsum(log_a, axis=1)                       # [B,S,nh]
    tail = jnp.exp(acum[:, -1:, :] - acum)
    xtail = xdt.astype(jnp.float32) * tail[..., None]      # [B,S,nh,hd]
    return jnp.einsum("bqhd,bqs->bhds", xtail, b.astype(jnp.float32))


def ssm_decode_step(cfg: ArchConfig, p, x, cache: SSMCache):
    """Single-token recurrent update.  x: [B,1,d]."""
    B = x.shape[0]
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xs, b, c, dt = _project(cfg, p, x)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_b"], p["conv_c"]], -1)
    feats = jnp.concatenate([xs, b, c], -1)                # [B,1,F]
    feats, conv_state = _causal_conv(feats, conv_w, cache.conv)
    xs, b, c = jnp.split(feats, [di, di + st], axis=-1)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0] * a)                              # [B,nh]
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    xdt = xh * dt[:, 0][..., None]
    h = cache.h * da[..., None, None] + \
        jnp.einsum("bhd,bs->bhds", xdt, b[:, 0].astype(jnp.float32))
    y = jnp.einsum("bhds,bs->bhd", h, c[:, 0].astype(jnp.float32))
    y = y + xh * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out"], SSMCache(conv_state.astype(cache.conv.dtype), h)
