"""AdamW with global-norm clipping and warmup-cosine schedule.

Functional, shard-friendly: optimizer moments mirror the parameter pytree
(and are sharded with `opt_rules` — ZeRO-1 over the data axis).  Moments
are fp32 regardless of parameter dtype; updates are applied in the
parameter dtype (no separate fp32 master copy — see DESIGN.md §6 memory
budget for the 398B config).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(jnp.zeros((), jnp.int32),
                      jax.tree.map(zeros, params),
                      jax.tree.map(zeros, params))


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in
           zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "grad_norm": gnorm, "lr": lr}
