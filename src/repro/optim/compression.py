"""Error-feedback int8 gradient compression (cross-pod DCN link).

Mirrors the paper's NVLink-vs-IB asymmetry: intra-pod reductions run at
ICI bandwidth, the `pod` axis crosses the DCN where bytes are 16× more
expensive — compressing the pod-axis all-reduce to int8 with error
feedback (residual accumulation, Seide et al. / EF-SGD) cuts that
collective term 4× vs f32 with negligible quality loss.

Two entry points:
* `ef_compress_grads` — pure pytree transform (quantize → dequantize with
  residual carry); composes with any optimizer and any sharding, and is
  what `make_train_step(compressor=...)` uses.
* `compressed_psum` — explicit shard_map psum in the int8 domain over a
  named axis (the pattern a custom DCN reducer uses); validated in tests
  on an 8-device host mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x, bits: int = 8):
    x = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x)) + 1e-12
    qmax = 2.0 ** (bits - 1) - 1
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_init(params) -> Any:
    """Residual (error-feedback) state, one per parameter."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_grads(grads, residual) -> Tuple[Any, Any]:
    """g' = Q(g + r);  r ← (g + r) − g'.  Returns (compressed-domain
    grads, new residual)."""
    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _quantize(x)
        deq = _dequantize(q, scale)
        return deq, x - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def compressed_psum(x, axis_name: str):
    """int8 quantize → psum → dequantize over `axis_name` (use inside
    shard_map).  All shards quantize against a shared scale (pmax of local
    amax) so the integer sum dequantizes exactly."""
    x = x.astype(jnp.float32)
    qmax = 127.0
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name) + 1e-12
    scale = amax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
