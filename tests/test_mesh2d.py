"""Named 2-D (config × trial) mesh equivalence tests (ISSUE 8).

The planet-scale sharding obligation: on a 4-device (simulated) host the
2×2 named mesh must reproduce the 1-D 4×1 layout, which must reproduce
the unsharded engines —

* `sharded_sweep`: the flat configuration axis product-shards over BOTH
  mesh axes (`batch_spec`), so (4, 1), (2, 2) and (1, 4) meshes all see
  the same per-device slabs in the same order; chunked streaming
  dispatch (`chunk_size`) must concatenate back to the one-shot result;
  non-divisible batches pad-and-drop.
* `sharded_mc_sweep`: `mesh_shape=(dc, dt)` with `dt > 1` block-shards
  the [B, T] grid (configs over CONFIG_AXIS, trials over TRIAL_AXIS)
  and must match the flat product-sharded layout and unsharded
  `mc_sweep`, including non-divisible B and T remainders.

This module forces 4 host devices when it is the first jax importer
(the test_sharded_sweep.py pattern); under the full 2-device tier-1 run
the 4-device cases skip and CI exercises them in a dedicated
``--xla_force_host_platform_device_count=4`` leg.
"""
import os
import sys

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=4").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core import hierarchy as h, placement as pl  # noqa: E402
from repro.core import projections as proj  # noqa: E402
from repro.core import quantiles as qt  # noqa: E402
from repro.core.arrivals import EnvelopeSpec  # noqa: E402
from repro.core.mc_sweep import MCAxes, mc_sweep, sharded_mc_sweep  # noqa: E402
from repro.core.sweep import SweepAxes, sharded_sweep, sweep  # noqa: E402
from repro.sharding import axes as shax  # noqa: E402

needs4 = pytest.mark.skipif(jax.device_count() < 4,
                            reason="needs >=4 host devices")

SCALE = 0.01


def _env(scenario):
    return EnvelopeSpec(demand_scale=SCALE, gpu_scenario=scenario)


def _grid8():
    return SweepAxes.product(
        designs=[h.get_design("4N/3"), h.get_design("3+1")],
        envs=[_env(proj.MED), _env(proj.HIGH)],
        seeds=(3, 4))


def _assert_sweeps_equal(a, b):
    """Same inputs, same per-config program, different device layout —
    tolerances are tight."""
    assert len(a) == len(b)
    np.testing.assert_array_equal(a.n_halls_built, b.n_halls_built)
    np.testing.assert_allclose(a.final_deployed_mw, b.final_deployed_mw,
                               rtol=1e-6)
    np.testing.assert_allclose(a.deployed_mw, b.deployed_mw,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(a.p50_stranding, b.p50_stranding, atol=1e-6)
    np.testing.assert_allclose(a.p90_stranding, b.p90_stranding, atol=1e-6)
    np.testing.assert_array_equal(a.halls_active, b.halls_active)
    np.testing.assert_allclose(a.final_hall_stranding,
                               b.final_hall_stranding, atol=1e-6)
    np.testing.assert_allclose(a.placed_fraction, b.placed_fraction,
                               atol=1e-7)


def _assert_mc_equal(a, b):
    assert len(a) == len(b) and a.n_trials == b.n_trials
    for key in ("saturated", "placed_a", "placed_b"):
        np.testing.assert_array_equal(getattr(a, key), getattr(b, key),
                                      err_msg=key)
    for key in ("lineup_stranding", "hall_stranding", "deployed_kw"):
        np.testing.assert_allclose(getattr(a, key), getattr(b, key),
                                   rtol=1e-6, atol=1e-5, err_msg=key)


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

@needs4
def test_sweep_mesh_shapes():
    """Default is (D, 1); any factorization of D is accepted; anything
    else is a ValueError, not a silent fallback."""
    assert shax.sweep_mesh().devices.shape == (jax.device_count(), 1)
    assert shax.sweep_mesh(shape=(2, 2)).devices.shape == (2, 2)
    assert shax.sweep_mesh(shape=(1, 4)).devices.shape == (1, 4)
    for bad in ((3, 2), (4, 2), (0, 4), (-2, -2)):
        with pytest.raises(ValueError):
            shax.sweep_mesh(shape=bad)


def test_axis_rules_product_shard():
    """The logical-axis table: 'batch' product-shards over both named
    axes, 'config'/'trial' map to their own axis."""
    assert shax.batch_spec() == jax.sharding.PartitionSpec(
        (shax.CONFIG_AXIS, shax.TRIAL_AXIS))
    assert shax.grid_spec() == jax.sharding.PartitionSpec(
        shax.CONFIG_AXIS, shax.TRIAL_AXIS)
    assert shax.config_spec() == jax.sharding.PartitionSpec(
        shax.CONFIG_AXIS)


# ---------------------------------------------------------------------------
# sharded_sweep on the 2-D mesh
# ---------------------------------------------------------------------------

@needs4
def test_sweep_2d_equals_1d_equals_unsharded():
    axes = _grid8()
    res_un = sweep(axes)
    res_1d = sharded_sweep(axes)                      # default (4, 1)
    res_2d = sharded_sweep(axes, mesh_shape=(2, 2))
    res_t4 = sharded_sweep(axes, mesh_shape=(1, 4))
    _assert_sweeps_equal(res_un, res_1d)
    _assert_sweeps_equal(res_un, res_2d)
    _assert_sweeps_equal(res_un, res_t4)


@needs4
def test_sweep_chunked_dispatch_matches_one_shot():
    """chunk_size=3 on 4 devices rounds up to 4-config chunks and pads
    the 8-config batch to two dispatches; result identical to the
    single-dispatch path."""
    axes = _grid8()
    res_one = sharded_sweep(axes, mesh_shape=(2, 2))
    res_chk = sharded_sweep(axes, mesh_shape=(2, 2), chunk_size=3)
    _assert_sweeps_equal(res_one, res_chk)


@needs4
def test_sweep_2d_remainder_batch():
    """5 configurations on a 2×2 mesh: pad to 8, drop the replicas."""
    axes = SweepAxes.zip(
        designs=[h.get_design("4N/3"), h.get_design("3+1"),
                 h.get_design("4N/3"), h.get_design("3+1"),
                 h.get_design("10N/8")],
        envs=[_env(proj.MED)],
        policies=[pl.POLICY_VAR_MIN, pl.POLICY_VAR_MIN,
                  pl.POLICY_MIN_WASTE, pl.POLICY_VAR_MIN,
                  pl.POLICY_VAR_MIN],
        seeds=[0, 0, 0, 1, 0])
    res_un = sweep(axes)
    res_2d = sharded_sweep(axes, mesh_shape=(2, 2))
    assert len(res_2d) == 5
    _assert_sweeps_equal(res_un, res_2d)


@needs4
def test_sweep_streaming_under_2d_mesh():
    """The streaming histogram path composes with 2-D sharding: sharded
    streaming ≡ unsharded streaming (tight), and within one bin of the
    exact quantiles."""
    axes = _grid8()
    res_s = sharded_sweep(axes, mesh_shape=(2, 2), exact_quantiles=False)
    res_u = sweep(axes, exact_quantiles=False)
    _assert_sweeps_equal(res_u, res_s)
    exact = sweep(axes)
    tol = 1.0 / qt.DEFAULT_BINS + 1e-6
    for attr in ("p50_stranding", "p90_stranding"):
        e = getattr(exact, attr)
        s = getattr(res_s, attr)
        ok = ~np.isnan(e)
        np.testing.assert_array_equal(np.isnan(e), np.isnan(s))
        np.testing.assert_allclose(s[ok], e[ok], atol=tol, err_msg=attr)


def test_sweep_single_device_passthrough():
    """devices=[one] is byte-for-byte `sweep`, whatever the host device
    count; streaming statics are forwarded through the passthrough."""
    axes = SweepAxes.zip(designs=[h.get_design("4N/3")],
                         envs=[_env(proj.MED), _env(proj.HIGH)])
    res_s = sharded_sweep(axes, devices=jax.devices()[:1],
                          exact_quantiles=False)
    res_b = sweep(axes, exact_quantiles=False)
    np.testing.assert_array_equal(res_s.final_deployed_mw,
                                  res_b.final_deployed_mw)
    np.testing.assert_array_equal(res_s.p90_stranding, res_b.p90_stranding)
    np.testing.assert_array_equal(res_s.n_halls_built, res_b.n_halls_built)


# ---------------------------------------------------------------------------
# sharded_mc_sweep on the 2-D mesh
# ---------------------------------------------------------------------------

MC_KW = dict(n_trials=6, n_events=120, year=2030, scenario=proj.HIGH)


def _mc_axes3():
    return MCAxes.zip(
        designs=[h.get_design(n) for n in ("4N/3", "3+1", "10N/8")],
        policies=[pl.POLICY_VAR_MIN, pl.POLICY_MIN_WASTE,
                  pl.POLICY_VAR_MIN],
        seeds=[11, 11, 13])


@needs4
def test_mc_2d_equals_flat_equals_unsharded():
    """B=3 (config remainder on dc=2), T=6: grid path ≡ flat product
    sharding ≡ unsharded."""
    axes = _mc_axes3()
    res_un = mc_sweep(axes, **MC_KW)
    res_flat = sharded_mc_sweep(axes, **MC_KW)        # default (4, 1)
    res_2d = sharded_mc_sweep(axes, mesh_shape=(2, 2), **MC_KW)
    _assert_mc_equal(res_un, res_flat)
    _assert_mc_equal(res_un, res_2d)


@needs4
def test_mc_2d_trial_remainder():
    """T=5 on dt=2 pads the trial axis to 6 and drops the replica
    column; every real trial matches the unsharded grid."""
    kw = dict(MC_KW, n_trials=5)
    axes = _mc_axes3()
    res_un = mc_sweep(axes, **kw)
    res_2d = sharded_mc_sweep(axes, mesh_shape=(2, 2), **kw)
    assert res_2d.hall_stranding.shape[:2] == (3, 5)
    _assert_mc_equal(res_un, res_2d)


@needs4
def test_mc_pod_path_under_2d_mesh():
    """The split-pods fast path composes with the 2-D grid layout."""
    kw = dict(MC_KW, n_trials=4, pod_racks=8)
    axes = MCAxes.zip(designs=[h.get_design("4N/3"), h.get_design("3+1")],
                      seeds=[5, 7])
    res_un = mc_sweep(axes, **kw)
    res_2d = sharded_mc_sweep(axes, mesh_shape=(2, 2), **kw)
    _assert_mc_equal(res_un, res_2d)
