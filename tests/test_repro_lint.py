"""Tests for tools/repro_lint: the fixture corpus, suppressions,
baselines, the CLI, and the committed repo baseline.

The fixture corpus under tests/fixtures/lint/ has one minimal
good/bad pair per rule.  Bad fixtures pin exact RL### codes *and*
line numbers so a checker regression (wrong node, wrong scope, off
by one) fails loudly rather than silently drifting.
"""
import json
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from tools.repro_lint import (  # noqa: E402
    RULES,
    apply_baseline,
    lint_paths,
    lint_source,
    load_baseline,
    write_baseline,
)
from tools.repro_lint.baseline import counts_of  # noqa: E402

FIXTURES = REPO / "tests" / "fixtures" / "lint"

# Every bad fixture, with the exact (code, line) diagnostics it must
# produce — nothing more, nothing less.
EXPECTED = {
    "rl000_bad.py": [("RL000", 2)],
    "rl101_bad.py": [("RL101", 14)],
    "rl102_bad.py": [("RL102", 13), ("RL102", 15)],
    "rl201_bad.py": [("RL201", 7)],
    "rl202_bad.py": [("RL202", 7), ("RL202", 8)],
    "rl203_bad.py": [("RL203", 7)],
    "rl301_bad.py": [("RL301", 7), ("RL301", 13)],
    "rl401_bad.py": [("RL401", 8), ("RL401", 9), ("RL401", 10)],
    "rl601_bad.py": [("RL601", 5), ("RL601", 6)],
    "kernels_bad_missing_ref": [("RL501", 1), ("RL503", 1)],
    "kernels_bad_sig": [("RL502", 4)],
    "kernels_bad_ops": [("RL503", 1)],
}

GOOD = [
    "rl101_good.py", "rl102_good.py", "rl201_good.py", "rl202_good.py",
    "rl203_good.py", "rl301_good.py", "rl401_good.py", "rl601_good.py",
    "suppressed.py", "kernels_good",
]


def lint_fixture(name):
    return lint_paths([str(FIXTURES / name)], REPO, include_fixtures=True)


# ---------------------------------------------------------------- fixtures

@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_bad_fixture_fires_exact_diagnostics(name):
    diags = lint_fixture(name)
    got = sorted((d.code, d.line) for d in diags)
    assert got == sorted(EXPECTED[name]), (
        f"{name}: expected {sorted(EXPECTED[name])}, got "
        f"{[(d.code, d.line, d.message) for d in diags]}")


@pytest.mark.parametrize("name", GOOD)
def test_good_fixture_is_silent(name):
    diags = lint_fixture(name)
    assert diags == [], [(d.code, d.line, d.message) for d in diags]


def test_every_rule_has_a_firing_fixture():
    """Meta-test: a rule nobody can trip is a rule nobody maintains."""
    fired = {code for codes in EXPECTED.values() for code, _ in codes}
    registered = set(RULES)
    assert registered == fired, (
        f"rules without a firing fixture: {sorted(registered - fired)}; "
        f"fixtures firing unregistered codes: {sorted(fired - registered)}")


def test_fixtures_are_skipped_by_default():
    """tests/fixtures/** is excluded unless include_fixtures is set,
    so the deliberately-bad corpus never pollutes a real lint run."""
    diags = lint_paths([str(FIXTURES)], REPO, include_fixtures=False)
    assert diags == []


# ------------------------------------------------------------ suppressions

def test_inline_disable_suppresses_only_that_line():
    src = (
        "import jax\n"
        "key = jax.random.PRNGKey(0)\n"
        "a = jax.random.uniform(key, (4,))\n"
        "b = jax.random.normal(key, (4,))  # repro-lint: disable=RL301\n"
        "c = jax.random.normal(key, (4,))\n"
    )
    diags = lint_source(src, "src/repro/core/fake.py", REPO)
    assert [(d.code, d.line) for d in diags] == [("RL301", 5)]


def test_disable_next_line():
    src = (
        "import time\n"
        "# repro-lint: disable-next-line=RL201\n"
        "t = time.time()\n"
        "u = time.time()\n"
    )
    diags = lint_source(src, "src/repro/core/fake.py", REPO)
    assert [(d.code, d.line) for d in diags] == [("RL201", 4)]


def test_path_pragma_overrides_scope():
    """The path= pragma makes a fixture lint as if it lived at the
    given repo path (scope selection only; reported path unchanged)."""
    src = (
        "# repro-lint: path=src/repro/launch/fake.py\n"
        "import time\n"
        "t = time.time()\n"
    )
    diags = lint_source(src, "src/repro/core/fake.py", REPO)
    assert diags == []  # launch/ is outside the deterministic core


# --------------------------------------------------------------- baselines

def test_baseline_round_trip(tmp_path):
    diags = lint_fixture("rl401_bad.py")
    assert len(diags) == 3
    bl = tmp_path / "bl.json"
    write_baseline(bl, diags)
    counts = load_baseline(bl)
    new, stale = apply_baseline(diags, counts)
    assert new == [] and stale == {}


def test_baseline_over_budget_reports_whole_group(tmp_path):
    diags = lint_fixture("rl401_bad.py")
    counts = counts_of(diags)
    key = next(iter(counts))
    counts[key] -= 1  # budget is now one short
    new, stale = apply_baseline(diags, counts)
    assert [d.code for d in new] == ["RL401"] * 3
    assert stale == {}


def test_baseline_stale_surplus_detected():
    diags = lint_fixture("rl401_bad.py")
    counts = counts_of(diags)
    key = next(iter(counts))
    counts[key] += 2
    counts["src/repro/gone.py::RL999"] = 1
    new, stale = apply_baseline(diags, counts)
    assert new == []
    assert stale == {key: 2, "src/repro/gone.py::RL999": 1}


def test_committed_baseline_is_empty_and_tree_is_clean():
    """The committed baseline must only ever shrink — and it starts at
    zero: the real tree lints clean with no grandfathered debt."""
    counts = load_baseline(REPO / ".repro-lint-baseline.json")
    assert sum(counts.values()) == 0, (
        f"baseline grew debt: {counts}")
    diags = lint_paths(
        ["src", "tests", "tools", "benchmarks", "examples"], REPO)
    new, _stale = apply_baseline(diags, counts)
    assert new == [], [d.format() for d in new]


# --------------------------------------------------------------------- CLI

def run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", *args],
        cwd=REPO, capture_output=True, text=True)


def test_cli_src_is_clean():
    proc = run_cli("src")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_fixtures_fail_with_findings():
    proc = run_cli("tests/fixtures/lint", "--include-fixtures")
    assert proc.returncode == 1
    assert "RL301" in proc.stdout


def test_cli_json_format():
    proc = run_cli("tests/fixtures/lint", "--include-fixtures",
                   "--format=json")
    assert proc.returncode == 1
    payload = json.loads(proc.stdout)
    codes = {f["code"] for f in payload["findings"]}
    assert "RL601" in codes and payload["baselined"] == 0


def test_cli_missing_path_is_usage_error():
    proc = run_cli("no/such/dir")
    assert proc.returncode == 2


def test_cli_list_rules():
    proc = run_cli("--list-rules")
    assert proc.returncode == 0
    for code in RULES:
        assert code in proc.stdout
