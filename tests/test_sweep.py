"""Sweep-engine equivalence and regression tests.

The batched engine pads topologies/traces to common shapes and vmaps
`simulate_lifecycle`; for score-based policies (min-waste, var-min) the
padding is provably inert, so each configuration of a sweep must
reproduce the sequential `run_fleet` outputs within float tolerance.
"""
import numpy as np
import pytest

from repro.core import hierarchy as h, placement as pl, projections as proj
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet
from repro.core.sweep import SweepAxes, sweep

SCALE = 0.01


def _env(scenario):
    return EnvelopeSpec(demand_scale=SCALE, gpu_scenario=scenario)


@pytest.fixture(scope="module")
def batch():
    axes = SweepAxes.zip(
        designs=[h.get_design(n)
                 for n in ("4N/3", "3+1", "4N/3", "10N/8")],
        envs=[_env(proj.HIGH), _env(proj.HIGH), _env(proj.MED),
              _env(proj.HIGH)],
        policies=[pl.POLICY_VAR_MIN, pl.POLICY_VAR_MIN,
                  pl.POLICY_MIN_WASTE, pl.POLICY_VAR_MIN],
        seeds=[3, 3, 5, 7])
    return axes, sweep(axes)


def test_sweep_matches_sequential(batch):
    axes, res = batch
    assert len(res) >= 4
    for i in range(len(axes)):
        r = run_fleet(axes.config(i))
        assert int(res.n_halls_built[i]) == r.n_halls_built
        np.testing.assert_allclose(res.final_deployed_mw[i],
                                   r.final_deployed_mw, rtol=1e-5)
        np.testing.assert_allclose(res.placed_fraction[i],
                                   r.placed_fraction, atol=1e-6)
        np.testing.assert_allclose(res.deployed_mw[i], r.deployed_mw,
                                   rtol=1e-5, atol=1e-3)
        np.testing.assert_allclose(res.p50_stranding[i], r.p50_stranding,
                                   atol=2e-3)
        np.testing.assert_allclose(res.p90_stranding[i], r.p90_stranding,
                                   atol=2e-3)
        np.testing.assert_allclose(res.halls_active[i], r.halls_active)
        np.testing.assert_allclose(res.effective_dpm[i], r.effective_dpm,
                                   rtol=1e-5)


def test_result_unpack_round_trips(batch):
    """SweepResult.result(i) must produce a FleetResult whose fields are
    self-consistent with the batched arrays (padding stripped)."""
    axes, res = batch
    for i in range(len(axes)):
        fr = res.result(i)
        assert fr.n_halls_built == int(res.n_halls_built[i])
        assert fr.final_hall_stranding.shape == (fr.n_halls_built,)
        assert fr.design is axes.designs[i]
        # only line-ups of built halls survive the active mask
        lph = res.lineups_per_hall
        n_active_lineups = int(res.lineup_is_active[i][
            :fr.n_halls_built * lph].sum())
        assert fr.final_lineup_stranding.shape == (n_active_lineups,)
        np.testing.assert_allclose(fr.p90_stranding,
                                   res.p90_stranding[i])


def test_sweep_axes_product_and_broadcast():
    axes = SweepAxes.product(
        designs=[h.get_design("4N/3"), h.get_design("3+1")],
        envs=[_env(proj.MED)], seeds=(0, 1))
    assert len(axes) == 4
    assert {d.name for d in axes.designs} == {"4N/3", "3+1"}
    z = SweepAxes.zip(designs=[h.get_design("4N/3")],
                      envs=[_env(proj.MED), _env(proj.HIGH)])
    assert len(z) == 2 and z.designs[0] is z.designs[1]
    with pytest.raises(ValueError):
        SweepAxes.zip(designs=[h.get_design("4N/3")] * 3,
                      envs=[_env(proj.MED)] * 2)


def test_sweep_rejects_mixed_horizons():
    with pytest.raises(ValueError):
        sweep(SweepAxes.zip(
            designs=[h.get_design("4N/3")],
            envs=[_env(proj.MED),
                  EnvelopeSpec(demand_scale=SCALE, end_year=2030)]))


def test_golden_regression():
    """Fixed-seed headline numbers for one configuration (3+1, High TDP,
    seed 3, 100 MW).  Guards the whole engine — trace generation,
    placement, harvest/decommission bookkeeping, percentile stats —
    against silent behavior drift."""
    r = run_fleet(FleetConfig(h.get_design("3+1"), _env(proj.HIGH),
                              seed=3))
    assert r.n_halls_built == 14
    assert r.placed_fraction == 1.0
    np.testing.assert_allclose(r.final_deployed_mw, 77.8758, atol=0.01)
    np.testing.assert_allclose(float(r.p50_stranding[-1]), 0.2407,
                               atol=2e-3)
    np.testing.assert_allclose(float(r.p90_stranding[-1]), 0.3062,
                               atol=2e-3)
    np.testing.assert_allclose(r.effective_dpm, 13.997e6, rtol=1e-3)
