"""Hypothesis property tests on system invariants."""
import pytest

pytest.importorskip("hypothesis")  # optional dev dep, requirements-dev.txt

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import hierarchy as h, placement as pl
from repro.core import throughput as tp, projections as proj
from repro.launch.hlo_analysis import _shape_bytes, parse_hlo

TOPO = h.build_topology(h.design_4n3())
JT = pl.jax_topology(TOPO)
TOPO_B = h.build_topology(h.design_3p1())
JT_B = pl.jax_topology(TOPO_B)


@settings(max_examples=12, deadline=None)
@given(st.lists(st.tuples(st.floats(5, 800), st.integers(1, 6),
                          st.booleans(), st.integers(0, 3)),
                min_size=1, max_size=15),
       st.integers(0, 2 ** 16))
def test_capacity_never_exceeded(seq, seed):
    """Invariant (Eq. 26): no placement sequence can overfill any node."""
    for jt, topo in ((JT, TOPO), (JT_B, TOPO_B)):
        state = pl.init_state(topo)
        key = jax.random.PRNGKey(seed)
        for i, (kw, n, gpu, policy) in enumerate(seq):
            dep = pl.Deployment.make(kw, n, is_gpu=gpu)
            state, ok, _, _ = pl.place(jt, state, dep, policy,
                                       jax.random.fold_in(key, i))
        assert (np.asarray(state.row_load)
                <= np.asarray(topo.row_cap) + 1e-2).all()
        eff = topo.ha_frac * np.asarray(topo.lineup_cap)
        assert (np.asarray(state.lineup_ha) <= eff + 1e-2).all()
        assert (np.asarray(state.hall_liq)
                <= np.asarray(topo.hall_liq_cap) + 1e-2).all()


@settings(max_examples=20, deadline=None)
@given(st.floats(10, 1200), st.integers(1, 7), st.booleans(),
       st.integers(0, 2 ** 16))
def test_place_release_is_identity(kw, n, gpu, seed):
    state0 = pl.init_state(TOPO)
    dep = pl.Deployment.make(kw, n, is_gpu=gpu, is_pod=gpu and n > 1)
    state1, ok, rows, counts = pl.place(JT, state0, dep, pl.POLICY_VAR_MIN,
                                        jax.random.PRNGKey(seed))
    if not bool(ok):
        return
    state2 = pl.release_bulk(JT, state1, rows[None], counts[None],
                             jnp.asarray([kw], jnp.float32),
                             jnp.asarray([gpu]), jnp.asarray([0]),
                             jnp.asarray([1.0]))
    for a, b in zip(jax.tree.leaves(state0._replace(rr_cursor=state2.rr_cursor)),
                    jax.tree.leaves(state2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 7), st.sampled_from(list(tp.MODELS)))
def test_tps_positive_and_pod_monotone(pod, mname):
    m = tp.MODELS[mname]
    d = tp.Deployment(proj.KYBER, 2028, pod, proj.HIGH)
    t = float(tp.tps_request(m, d))
    assert t > 0
    assert 0.0 <= tp.f_ib(m, d) < 1.0
    d1 = tp.Deployment(proj.KYBER, 2028, 1, proj.HIGH)
    assert tp.tps_per_watt(m, d) >= tp.tps_per_watt(m, d1) * 0.999


@settings(max_examples=25, deadline=None)
@given(st.integers(2025, 2040), st.sampled_from(list(proj.SCENARIOS)))
def test_projections_monotone_in_scenario(year, scenario):
    lo = proj.gpu_rack_kw(year, proj.LOW)
    hi = proj.gpu_rack_kw(year, proj.HIGH)
    mid = proj.gpu_rack_kw(year, proj.MED)
    assert lo <= mid <= hi
    assert proj.gpu_rack_kw(year, scenario) > 0


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(["pred", "bf16", "f32", "s32"]),
       st.lists(st.integers(1, 64), min_size=0, max_size=4))
def test_shape_bytes(dtype, dims):
    n = int(np.prod(dims)) if dims else 1
    per = {"pred": 1, "bf16": 2, "f32": 4, "s32": 4}[dtype]
    assert _shape_bytes(dtype, ",".join(map(str, dims))) == n * per


@settings(max_examples=15, deadline=None)
@given(st.sampled_from([5, 12, 17]), st.integers(2, 5),
       st.floats(10, 1500), st.floats(0.25, 1.0), st.booleans(),
       st.booleans(), st.integers(0, 2 ** 16))
def test_score_rows_matches_reference(R, F, p_dep, ha_frac, is_ha,
                                      is_block, seed):
    """Property: the Pallas `score_rows` path (interpret mode, padded to
    block_r tiles) agrees with the pure-jnp `reference_score` oracle on
    random feed maps / loads — feasibility bitwise, scores to f32 ulps."""
    from repro.kernels.placement_score.ops import score_rows
    from repro.kernels.placement_score.ref import reference_score
    rng = np.random.default_rng(seed)
    X = 6
    feeds = np.where(rng.random((R, F)) < 0.25, -1,
                     rng.integers(0, X, (R, F))).astype(np.int32)
    nfeeds = (feeds >= 0).sum(-1).astype(np.int32)
    ha = rng.uniform(0, 2000, X).astype(np.float32)
    tot = (ha + rng.uniform(0, 400, X)).astype(np.float32)
    caps = np.full((X,), 2500.0, np.float32)
    row_cap = rng.uniform(400, 900, R).astype(np.float32)
    row_load = rng.uniform(0, 500, R).astype(np.float32)
    feas_k, score_k = score_rows(feeds, nfeeds, row_cap, ha, tot, caps,
                                 row_load, p_dep, ha_frac, is_ha, is_block,
                                 block_r=16, interpret=True)
    safe = np.where(feeds >= 0, feeds, 0)
    valid = (feeds >= 0).astype(np.float32)
    params = jnp.array([p_dep, ha_frac, float(is_ha), float(is_block)],
                       jnp.float32)
    feas_r, score_r = reference_score(
        jnp.asarray(ha[safe]), jnp.asarray(tot[safe]),
        jnp.asarray(caps[safe]), jnp.asarray(valid), jnp.asarray(nfeeds),
        jnp.asarray(row_load), jnp.asarray(row_cap), params)
    np.testing.assert_array_equal(np.asarray(feas_k),
                                  np.asarray(feas_r) > 0)
    np.testing.assert_allclose(np.asarray(score_k), np.asarray(score_r),
                               rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 6), st.floats(50, 900), st.booleans(),
       st.integers(0, 3), st.integers(0, 2 ** 16))
def test_kernel_subset_padding_never_wins(n_hd, kw, gpu, policy, seed):
    """Property: restricting the kernel path to an HD-compacted subset
    (padded internally to block_r tiles) never selects a padded/masked
    row — the chosen row and resulting state are bitwise the jnp path's."""
    dep = pl.Deployment.make(kw, 1, is_gpu=gpu)
    key = jax.random.PRNGKey(seed)
    active = jnp.ones((TOPO.row_cap.shape[0],), bool)
    rows = JT.hd_index[:max(n_hd, 1)]
    st_j, ok_j, row_j = pl.place_in_row(JT, pl.init_state(TOPO), dep, 1,
                                        policy, key, active,
                                        row_subset=rows)
    st_k, ok_k, row_k = pl.place_in_row(JT, pl.init_state(TOPO), dep, 1,
                                        policy, key, active,
                                        row_subset=rows, use_kernel=True,
                                        interpret=True)
    assert bool(ok_j) == bool(ok_k)
    assert int(row_j) == int(row_k)
    if bool(ok_k):   # selection stayed inside the real subset
        assert int(row_k) in np.asarray(rows).tolist()
    for a, b in zip(jax.tree.leaves(st_j), jax.tree.leaves(st_k)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_hlo_parser_on_synthetic_module():
    txt = """HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %y = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%i2, %y)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%z, %a)
  %w = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[8,16] get-tuple-element(%w), index=1
}
"""
    from repro.launch.hlo_analysis import analyze
    cost = analyze(txt, 1)
    # 12 loop trips × (2·8·16·16) flops per dot
    assert cost.flops == 12 * 2 * 8 * 16 * 16
    assert cost.n_while == 1
