"""Multi-device sharded-sweep equivalence tests (CPU host devices).

`sharded_sweep` shard_maps the configuration axis over a 1-D device mesh;
configurations are independent, so every metric must match single-device
`sweep` (tight tolerance) and sequential `run_fleet` (the PR 1 padding
tolerances).

These tests must force the device count BEFORE jax initializes; when the
full suite runs in one process jax is usually already initialized with 1
device — then the mesh tests skip.  CI exercises them by exporting
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` for the whole
tier-1 run; standalone `pytest tests/test_sharded_sweep.py` forces it
here.  The single-device passthrough test always runs."""
import os
import sys

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core import hierarchy as h, placement as pl  # noqa: E402
from repro.core import projections as proj  # noqa: E402
from repro.core.arrivals import EnvelopeSpec  # noqa: E402
from repro.core.fleet import run_fleet  # noqa: E402
from repro.core.sweep import SweepAxes, sharded_sweep, sweep  # noqa: E402

needs_devices = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >=2 host devices")

SCALE = 0.01


def _env(scenario):
    return EnvelopeSpec(demand_scale=SCALE, gpu_scenario=scenario)


def _grid8():
    """8 configurations: 2 designs × 2 scenarios × 2 seeds."""
    return SweepAxes.product(
        designs=[h.get_design("4N/3"), h.get_design("3+1")],
        envs=[_env(proj.MED), _env(proj.HIGH)],
        seeds=(3, 4))


def _assert_sweeps_match(res_1, res_d):
    """Sharded vs single-device: same inputs, same per-config program —
    only the device decomposition differs, so tolerances are tight."""
    assert len(res_1) == len(res_d)
    np.testing.assert_array_equal(res_1.n_halls_built, res_d.n_halls_built)
    np.testing.assert_allclose(res_1.final_deployed_mw,
                               res_d.final_deployed_mw, rtol=1e-6)
    np.testing.assert_allclose(res_1.deployed_mw, res_d.deployed_mw,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(res_1.p50_stranding, res_d.p50_stranding,
                               atol=1e-6)
    np.testing.assert_allclose(res_1.p90_stranding, res_d.p90_stranding,
                               atol=1e-6)
    np.testing.assert_array_equal(res_1.halls_active, res_d.halls_active)
    np.testing.assert_allclose(res_1.placed_fraction, res_d.placed_fraction,
                               atol=1e-7)
    np.testing.assert_allclose(res_1.final_hall_stranding,
                               res_d.final_hall_stranding, atol=1e-6)
    np.testing.assert_allclose(res_1.final_lineup_stranding,
                               res_d.final_lineup_stranding, atol=1e-6)
    np.testing.assert_allclose(res_1.effective_dpm, res_d.effective_dpm,
                               rtol=1e-6)


@needs_devices
def test_sharded_matches_sweep_and_sequential():
    """Acceptance: sharded ≡ single-device ≡ sequential on a ≥8-config
    grid under 2 (simulated) host devices."""
    axes = _grid8()
    assert len(axes) >= 8
    res_1 = sweep(axes)
    res_d = sharded_sweep(axes)
    _assert_sweeps_match(res_1, res_d)
    # spot-check the sequential reference on a design/scenario/seed spread
    for i in (0, 3, 6):
        r = run_fleet(axes.config(i))
        assert int(res_d.n_halls_built[i]) == r.n_halls_built
        np.testing.assert_allclose(res_d.final_deployed_mw[i],
                                   r.final_deployed_mw, rtol=1e-5)
        np.testing.assert_allclose(res_d.p90_stranding[i], r.p90_stranding,
                                   atol=2e-3)
        np.testing.assert_allclose(res_d.placed_fraction[i],
                                   r.placed_fraction, atol=1e-6)


@needs_devices
def test_sharded_remainder_grid():
    """5 configurations on 2 devices: the batch pads to 6, the replica is
    dropped, and every real configuration still matches."""
    axes = SweepAxes.zip(
        designs=[h.get_design("4N/3"), h.get_design("3+1"),
                 h.get_design("4N/3"), h.get_design("3+1"),
                 h.get_design("10N/8")],
        envs=[_env(proj.MED)],
        policies=[pl.POLICY_VAR_MIN, pl.POLICY_VAR_MIN, pl.POLICY_MIN_WASTE,
                  pl.POLICY_VAR_MIN, pl.POLICY_VAR_MIN],
        seeds=[0, 0, 0, 1, 0])
    assert len(axes) % jax.device_count() != 0
    res_1 = sweep(axes)
    res_d = sharded_sweep(axes)
    assert len(res_d) == 5
    _assert_sweeps_match(res_1, res_d)


@needs_devices
def test_sharded_result_unpacks():
    """SweepResult.result(i) works identically on sharded outputs."""
    axes = SweepAxes.zip(designs=[h.get_design("4N/3"),
                                  h.get_design("3+1")],
                         envs=[_env(proj.MED)])
    res = sharded_sweep(axes)
    for i in range(len(axes)):
        fr = res.result(i)
        assert fr.n_halls_built == int(res.n_halls_built[i])
        assert fr.final_hall_stranding.shape == (fr.n_halls_built,)


def test_single_device_passthrough():
    """On one device `sharded_sweep` must be byte-for-byte `sweep` (it is
    a passthrough); runs regardless of the host device count."""
    axes = SweepAxes.zip(designs=[h.get_design("4N/3")],
                         envs=[_env(proj.MED), _env(proj.HIGH)])
    res_s = sharded_sweep(axes, devices=jax.devices()[:1])
    res_b = sweep(axes)
    np.testing.assert_array_equal(res_s.final_deployed_mw,
                                  res_b.final_deployed_mw)
    np.testing.assert_array_equal(res_s.p90_stranding, res_b.p90_stranding)
    np.testing.assert_array_equal(res_s.n_halls_built, res_b.n_halls_built)
