"""Checkpoint/restart, straggler mitigation, compression, data pipeline,
serving engine."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (LEAVES, MANIFEST, Checkpointer,
                                           ChecksumError,
                                           manifest_fingerprint)
from repro.configs.base import get_smoke_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.api import build_model
from repro.optim import adamw
from repro.optim.compression import ef_compress_grads, ef_init
from repro.runtime.fault import (Backoff, NodeFailure, StragglerPolicy,
                                 Supervisor)
from repro.serve.engine import Request, ServeEngine
from repro.train.step import make_train_step

KEY = jax.random.PRNGKey(0)


class TestCheckpointer:
    def test_save_restore_roundtrip(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2)
        state = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        ckpt.save(5, state, blocking=True)
        restored, step = ckpt.restore(state)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10.0))

    def test_async_and_gc(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=2)
        state = {"x": jnp.zeros(100)}
        for s in (1, 2, 3, 4):
            ckpt.save(s, {"x": jnp.full(100, float(s))})
        ckpt.wait()
        assert ckpt.all_steps() == [3, 4]
        restored, step = ckpt.restore(state)
        assert step == 4 and float(restored["x"][0]) == 4.0

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, {"x": jnp.ones(3)}, blocking=True)
        # simulate a crash mid-save at step 2: directory without COMMIT
        os.makedirs(tmp_path / "step_00000002")
        assert ckpt.latest_step() == 1

    def test_restore_detects_structure_mismatch(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, {"x": jnp.ones(3)}, blocking=True)
        with pytest.raises(ValueError):
            ckpt.restore({"x": jnp.ones(3), "y": jnp.ones(2)})

    def test_load_returns_host_leaves_and_meta(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(3, {"a": jnp.arange(4.0), "b": jnp.ones(2)},
                  blocking=True)
        leaves, meta = ckpt.load()
        assert meta["step"] == 3 and len(leaves) == 2
        assert all(isinstance(x, np.ndarray) for x in leaves)
        np.testing.assert_array_equal(leaves[0], np.arange(4.0))

    def test_torn_payload_raises_checksum_error(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, {"x": jnp.arange(8.0)}, blocking=True)
        payload = tmp_path / "step_00000001" / LEAVES
        raw = bytearray(payload.read_bytes())
        raw[-1] ^= 0xFF                       # flip a byte: torn write
        payload.write_bytes(bytes(raw))
        with pytest.raises(ChecksumError):
            ckpt.load(step=1)
        with pytest.raises(ChecksumError):
            ckpt.restore({"x": jnp.arange(8.0)}, step=1)
        # verify=False is an explicit escape hatch
        leaves, _ = ckpt.load(step=1, verify=False)
        assert len(leaves) == 1

    def test_no_tmp_dirs_left_after_save(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path))
        ckpt.save(1, {"x": jnp.ones(3)}, blocking=True)
        names = os.listdir(tmp_path)
        assert not [n for n in names if n.endswith(".tmp")]
        assert "step_00000001" in names

    def test_fingerprints_time_independent(self, tmp_path, monkeypatch):
        """Two saves of identical state at different wall clocks must be
        identical in every fingerprint-covered byte: same leaves.npz
        bytes, same payload sha256, same manifest_fingerprint.  Only
        the manifest's volatile `time` key may differ."""
        import json as _json

        import repro.checkpoint.checkpointer as ckpt_mod

        state = {"a": jnp.arange(10.0), "b": jnp.ones((3, 4))}
        metas, payloads = [], []
        for i, fake_now in enumerate((1_000_000.0, 2_000_000.0)):
            monkeypatch.setattr(ckpt_mod.time, "time", lambda t=fake_now: t)
            d = tmp_path / f"run{i}"
            Checkpointer(str(d)).save(5, state, blocking=True)
            step_dir = d / "step_00000005"
            payloads.append((step_dir / LEAVES).read_bytes())
            metas.append(_json.loads((step_dir / MANIFEST).read_text()))
        assert metas[0]["time"] != metas[1]["time"]  # clocks really moved
        assert payloads[0] == payloads[1]
        assert metas[0]["sha256"] == metas[1]["sha256"]
        assert manifest_fingerprint(metas[0]) == manifest_fingerprint(metas[1])
        # the fingerprint covers the deterministic keys: corrupting one
        # changes it, while bumping `time` does not
        bumped = dict(metas[0], time=123.0)
        assert manifest_fingerprint(bumped) == manifest_fingerprint(metas[0])
        assert (manifest_fingerprint(dict(metas[0], step=6))
                != manifest_fingerprint(metas[0]))


class TestSupervisor:
    def test_restart_on_failure_resumes_from_checkpoint(self, tmp_path):
        ckpt = Checkpointer(str(tmp_path), keep=3)
        failures = {"armed": True}

        def step_fn(state, step):
            if step == 7 and failures["armed"]:
                failures["armed"] = False
                raise NodeFailure("simulated host loss")
            return state + 1, {"loss": float(state)}

        sup = Supervisor(
            step_fn=step_fn,
            save_fn=lambda s, st: ckpt.save(s, jnp.asarray(st),
                                            blocking=True),
            restore_fn=lambda: ckpt.restore(jnp.zeros(())),
            checkpoint_every=5)
        state, step, history, restarts = sup.run(jnp.zeros(()), 0, 12)
        assert restarts == 1 and step == 12
        # work replays from step 5 (last checkpoint), final state consistent
        assert float(state) == 12 - 5 + 5

    def test_straggler_detection(self):
        pol = StragglerPolicy(window=8, threshold=2.0, max_flags=1)
        fired = []
        for i in range(10):
            hit = pol.observe(i, 1.0 if i != 8 else 5.0)
            if hit:
                fired.append(i)
        assert fired == [8]
        assert pol.events and pol.events[0]["step"] == 8

    def test_straggler_streak_requires_consecutive_steps(self):
        """Slow steps separated by fast steps (or step gaps) never
        accumulate into a firing; only a true consecutive run fires."""
        pol = StragglerPolicy(window=8, threshold=2.0, max_flags=2)
        fired = []
        # slow at 8 and 10, fast at 9 in between — streak resets
        for i in range(12):
            if pol.observe(i, 5.0 if i in (8, 10) else 1.0):
                fired.append(i)
        assert fired == []
        # slow at 20 and 25 with a gap in step indices — also no firing
        pol2 = StragglerPolicy(window=8, threshold=2.0, max_flags=2)
        for i in range(8):
            pol2.observe(i, 1.0)
        assert not pol2.observe(20, 5.0)
        assert not pol2.observe(25, 5.0)
        # genuinely consecutive slow steps do fire
        pol3 = StragglerPolicy(window=8, threshold=2.0, max_flags=2)
        for i in range(8):
            pol3.observe(i, 1.0)
        assert not pol3.observe(8, 5.0)
        assert pol3.observe(9, 5.0)


class TestBackoff:
    def test_schedule_is_exponential_and_capped(self):
        b = Backoff(base_s=0.1, factor=2.0, cap_s=0.5, max_retries=5)
        assert b.delays() == [0.1, 0.2, 0.4, 0.5, 0.5]

    def test_zero_base_sleeps_instantly(self):
        b = Backoff(base_s=0.0, max_retries=3)
        t0 = time.time()
        for i in range(3):
            b.sleep(i)
        assert time.time() - t0 < 0.05
        assert b.delays() == [0.0, 0.0, 0.0]


class TestCompression:
    def test_ef_residual_preserves_signal(self):
        g = {"w": jax.random.normal(KEY, (64, 64)) * 1e-3}
        res = ef_init(g)
        # summed compressed grads over many steps ≈ summed true grads
        tot_c = jnp.zeros((64, 64))
        for i in range(20):
            gi = {"w": jax.random.normal(jax.random.fold_in(KEY, i),
                                         (64, 64)) * 1e-3}
            gc, res = ef_compress_grads(gi, res)
            tot_c = tot_c + gc["w"]
        # residual is bounded by one quantization step
        assert float(jnp.abs(res["w"]).max()) < 1e-3

    def test_compressed_training_still_converges(self):
        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        params = model.init(KEY)
        opt = adamw.init(params)
        res = ef_init(params)
        opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=1)
        batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab)}

        @jax.jit
        def step(params, opt, res):
            (loss, _), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            grads, res = ef_compress_grads(grads, res)
            params, opt, _ = adamw.update(opt_cfg, grads, opt, params)
            return params, opt, res, loss

        losses = []
        for _ in range(8):
            params, opt, res, loss = step(params, opt, res)
            losses.append(float(loss))
        assert losses[-1] < losses[0]   # memorizes the fixed batch


class TestDataPipeline:
    def test_deterministic_and_resumable(self):
        cfg = PipelineConfig(batch=4, seq=16, vocab=1000, seed=3)
        p1 = TokenPipeline(cfg)
        b1 = p1._batch_at(7)
        p2 = TokenPipeline(cfg)
        p2.load_state_dict({"step": 7})
        b2 = p2._batch_at(7)
        np.testing.assert_array_equal(b1, b2)

    def test_shards_disjoint(self):
        a = TokenPipeline(PipelineConfig(2, 8, 100, shard_id=0, num_shards=2))
        b = TokenPipeline(PipelineConfig(2, 8, 100, shard_id=1, num_shards=2))
        assert not np.array_equal(a._batch_at(0), b._batch_at(0))

    def test_prefetch_thread(self):
        p = TokenPipeline(PipelineConfig(2, 8, 100)).start()
        it = iter(p)
        batches = [next(it) for _ in range(3)]
        p.stop()
        assert all(b["tokens"].shape == (2, 9) for b in batches)


class TestServeEngine:
    def test_continuous_batching_drains(self):
        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        params = model.init(KEY)
        eng = ServeEngine(model, params, batch_slots=3, max_seq=64,
                          prompt_len=8)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, cfg.vocab, 8), max_new_tokens=6)
                for i in range(7)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_drained(max_steps=200)
        assert all(r.done for r in reqs)
        assert all(len(r.output) >= 6 for r in reqs)
        assert eng.stats["prefills"] == 7
        # more requests than slots ⇒ decode steps exceed one wave
        assert eng.stats["decode_steps"] >= 6

    def test_engine_matches_raw_decode(self):
        """Slot-0 tokens match a direct prefill+decode of the same prompt."""
        cfg = get_smoke_config("mamba2-2.7b")
        model = build_model(cfg)
        params = model.init(KEY)
        prompt = np.arange(1, 9, dtype=np.int32)
        eng = ServeEngine(model, params, batch_slots=2, max_seq=32,
                          prompt_len=8)
        r = Request(0, prompt, max_new_tokens=5)
        eng.submit(r)
        eng.run_until_drained(max_steps=50)

        logits, caches = model.prefill(
            params, {"tokens": jnp.asarray(prompt)[None]}, 32)
        toks = [int(jnp.argmax(logits[0]))]
        pos = 8
        for _ in range(4):
            lg, caches = model.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32),
                jnp.asarray(pos, jnp.int32), caches)
            toks.append(int(jnp.argmax(lg[0])))
            pos += 1
        assert r.output[:5] == toks
