"""The multi-pod dry-run deliverable: every (arch × applicable shape ×
mesh) cell must have compiled successfully (artifacts checked in under
experiments/dryrun).  Skips if the dry-run has not been executed."""
import glob
import json
import os

import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import shapes as sh

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


@pytest.fixture(scope="module")
def records():
    files = glob.glob(os.path.join(DRYRUN, "*.json"))
    if not files:
        pytest.skip("dry-run artifacts not present (run repro.launch.dryrun)")
    out = {}
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        out[os.path.basename(f)[:-5]] = r
    return out


def test_all_cells_compiled(records):
    missing, errored = [], []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        for shape in sh.applicable_cells(cfg):
            for mesh in ("16x16", "2x16x16"):
                tag = f"{arch_id}__{shape}__{mesh}"
                if tag not in records:
                    missing.append(tag)
                elif "error" in records[tag]:
                    errored.append(tag)
    assert not missing, f"missing dry-run cells: {missing}"
    assert not errored, f"failed dry-run cells: {errored}"


def test_roofline_terms_present_and_positive(records):
    for tag, r in records.items():
        if "error" in r:
            continue
        assert r["t_memory"] > 0, tag
        assert r["collective_bytes_per_device"] >= 0, tag
        assert r["flops_per_device"] > 0, tag
        assert r["bottleneck"] in ("compute", "memory", "collective"), tag


def test_multi_pod_pod_axis_shards(records):
    """The 2×16×16 pass proves the `pod` axis shards: per-device train
    compute must not exceed the single-pod value (more chips ⇒ ≤ work),
    modulo CP recompute overhead on optimized variants."""
    for arch_id in ARCH_IDS:
        a = records.get(f"{arch_id}__train_4k__16x16")
        b = records.get(f"{arch_id}__train_4k__2x16x16")
        if not a or not b or "error" in a or "error" in b:
            continue
        assert b["t_compute"] <= a["t_compute"] * 1.35, arch_id


def test_jamba_fsdp_fits_optimizer(records):
    r = records.get("jamba-1.5-large-398b__train_4k__2x16x16")
    if not r or "error" in r:
        pytest.skip("cell absent")
    # FSDP: params+opt state per device far below the TP-only 62.5 GB
    assert r["argument_size_in_bytes"] / 1e9 < 20
