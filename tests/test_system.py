"""End-to-end behaviour of the paper's system: demand projections →
arrival trace → fleet placement → cost → serving throughput, glued the
way Fig. 8 describes, with the paper's qualitative claims asserted."""
import numpy as np
import pytest

from repro.core import cost, hierarchy as h, payoff, projections as proj
from repro.core import throughput as tp
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet


@pytest.fixture(scope="module")
def pipeline():
    """One reduced-scale run of the full evaluation pipeline.  The 7.5 MW
    pair gives ~30 halls at this scale — the 20 MW pair would leave <10
    halls, where seed noise can flip the ordering (the paper's own Fig. 13
    shows 10N/8 vs 8+2 as the closest pair)."""
    env = EnvelopeSpec(demand_scale=0.015, gpu_scenario=proj.HIGH,
                       pod_racks=3, pod_scale_arch=True)
    out = {}
    for name in ("4N/3", "3+1"):
        out[name] = run_fleet(FleetConfig(h.get_design(name), env, seed=1))
    return env, out


def test_lifecycle_separates_designs_static_metrics_do_not(pipeline):
    """§3.1: similar nameplate + base cost, different lifecycle outcome."""
    env, results = pipeline
    d43, d31 = h.get_design("4N/3"), h.get_design("3+1")
    # static: same HA capacity, ≲3% cost gap
    assert d43.ha_capacity_kw == d31.ha_capacity_kw
    static_gap = abs(cost.initial_dollars_per_mw(d31)
                     / cost.initial_dollars_per_mw(d43) - 1)
    assert static_gap < 0.04
    # lifecycle: effective-cost gap exceeds the static gap
    r43, r31 = results["4N/3"], results["3+1"]
    lifecycle_gap = r31.effective_dpm / r43.effective_dpm - 1
    assert lifecycle_gap > static_gap - 0.02
    assert r31.p90_stranding[-1] >= r43.p90_stranding[-1] - 0.02


def test_deployable_capacity_not_installed_mw(pipeline):
    """The paper's thesis: installed MW ≠ deployable MW."""
    _, results = pipeline
    for r in results.values():
        installed = r.n_halls_built * r.design.ha_capacity_kw / 1e3
        assert r.final_deployed_mw < installed


def test_throughput_feeds_fleet_metric(pipeline):
    """Fig. 2 metric: TPS/W against effective $/W across the fleet."""
    _, results = pipeline
    m = tp.MODELS["MoE-132T"]
    pts = []
    for name, r in results.items():
        d = tp.Deployment(proj.KYBER, 2028, 3, proj.HIGH)
        pts.append((tp.tps_per_watt(m, d), r.effective_dpm))
    assert all(t > 0 and c > 0 for t, c in pts)


def test_pod_payoff_sign_structure():
    """§6.5: payoff ≤ ~0 for domain-fitting models, positive for models
    that span domains (serving gain beats deployability cost)."""
    env = EnvelopeSpec(demand_scale=0.015, gpu_scenario=proj.HIGH,
                       pod_scale_arch=True)
    pts = payoff.pod_payoff_study(
        h.get_design("10N/8"),
        [tp.MODELS["MoE-0.6T"], tp.MODELS["MoE-401T"]],
        pod_sizes=(1, 5), env=env, seed=2)
    by = {(p.model, p.pod_racks): p for p in pts}
    small = by[("MoE-0.6T", 5)]
    big = by[("MoE-401T", 5)]
    assert small.d_tps_per_watt < 0.01          # no serving gain
    assert big.d_tps_per_watt > 0.1             # real serving gain
    assert big.payoff > small.payoff
