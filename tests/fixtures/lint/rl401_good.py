# repro-lint: path=src/repro/kernels/fixture/ops.py
"""RL401 nearest-miss: float32 creation, and the float64 *guard* from
the real ops.py (a comparison creates nothing)."""
import jax.numpy as jnp


def require_f32(x):
    if x.dtype == jnp.float64:
        raise TypeError("cast to float32 first")
    return x.astype(jnp.float32)


def make(n):
    return jnp.zeros(n, dtype=jnp.float32)
