"""RL102: Python `if`/`while` on a non-static param of a jitted fn."""
import functools

import jax

_STATICS = ("flag",)


@functools.partial(jax.jit, static_argnames=_STATICS)
def run(x, n, flag=False):
    if flag:            # static: fine
        x = x + 1
    if n > 0:           # line 14: RL102 (`n` is traced)
        x = x * 2
    while n > 1:        # line 16: RL102
        x = x - 1
    return x
