"""RL501 + RL503: a kernel package with neither ref.py nor ops.py."""


def foo_kernel(x, scale, block_n=128, interpret=False):
    return x * scale
