"""RL601 nearest-miss: declared axes, empty specs, and variables."""
import jax
from jax.sharding import PartitionSpec as P

spec = P("config")
grid = P("config", "trial")
empty = P()
mesh = jax.make_mesh((1, 1), ("config", "trial"))


def by_name(axis):
    return P(axis)      # non-literal: out of scope
