# repro-lint: path=src/repro/kernels/fixture/ops.py
"""RL401: float64 creation in a kernel-reachable module."""
import jax.numpy as jnp
import numpy as np


def widen(x):
    hi = x.astype(jnp.float64)              # line 8: RL401
    pad = jnp.zeros(3, dtype=jnp.float64)   # line 9: RL401
    one = np.float64(1.0)                   # line 10: RL401
    return hi + pad + one
