"""RL301 nearest-miss: split/fold_in between draws, branch-exclusive
use, and the `key, sub = split(key)` rebinding idiom."""
import jax

key = jax.random.PRNGKey(0)
key, k_fill = jax.random.split(key)
fill = jax.random.uniform(k_fill, (8,))
refill = jax.random.normal(jax.random.fold_in(key, 1), (8,))


def per_step(key, steps, fancy=False):
    out = []
    for i in range(steps):
        out.append(jax.random.uniform(jax.random.fold_in(key, i), ()))
    return out


def branchy(key, fancy):
    if fancy:
        return jax.random.normal(key, ())
    return jax.random.uniform(key, ())
