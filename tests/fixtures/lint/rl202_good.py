# repro-lint: path=src/repro/core/fixture_rl202.py
"""RL202 nearest-miss: seeded generators are the sanctioned pattern."""
import numpy as np


def draw(n, seed):
    rng = np.random.default_rng(seed)
    salted = np.random.default_rng(seed=int(seed) + 1)
    return rng.normal(size=n) + salted.normal(size=n)
