"""Well-formed kernel package: kernel + mirroring ref + interpret ops."""


def foo_kernel(x, scale, block_n=128, interpret=False):
    return x * scale
