"""Oracle: public params are an ordered subset of the kernel entry's
(the kernel adds trailing tuning knobs)."""


def reference_foo(x, scale):
    return x * scale
