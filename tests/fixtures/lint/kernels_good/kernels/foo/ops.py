"""Ops wrapper exposing the interpret path."""
from .kernel import foo_kernel


def foo(x, scale, block_n=128, interpret=False):
    return foo_kernel(x, scale, block_n=block_n, interpret=interpret)
