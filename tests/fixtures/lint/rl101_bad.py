"""RL101: traced-array expression passed to a static_argnames arg."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode",))
def run(x, mode="fast"):
    return x * (2 if mode == "fast" else 3)


def caller(x):
    return run(x, mode=jnp.asarray(1))  # line 14: RL101
