"""RL503: ops.py exists but exposes no interpret path."""


def foo_kernel(x, scale, block_n=128, interpret=False):
    return x * scale
