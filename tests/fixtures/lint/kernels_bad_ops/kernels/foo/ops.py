"""Ops wrapper with no `interpret` parameter anywhere: RL503."""
from .kernel import foo_kernel


def foo(x, scale, block_n=128):
    return foo_kernel(x, scale, block_n=block_n)
