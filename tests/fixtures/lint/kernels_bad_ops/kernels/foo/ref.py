"""Matching oracle (keeps this tree RL503-only)."""


def reference_foo(x, scale):
    return x * scale
