"""Suppression fixture: every violation here carries an inline
disable, so this file must lint clean."""
import jax

key = jax.random.PRNGKey(0)
a = jax.random.uniform(key, (4,))
b = jax.random.normal(key, (4,))  # repro-lint: disable=RL301

# repro-lint: disable-next-line=RL601
from jax.sharding import PartitionSpec as P  # noqa: E402
# repro-lint: disable-next-line=RL601
spec = P("not-an-axis")
