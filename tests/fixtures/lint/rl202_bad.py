# repro-lint: path=src/repro/core/fixture_rl202.py
"""RL202: unseeded / global numpy RNG in the deterministic core."""
import numpy as np


def draw(n):
    rng = np.random.default_rng()      # line 7: RL202 (unseeded)
    noise = np.random.rand(n)          # line 8: RL202 (global state)
    return rng.normal(size=n) + noise
