"""RL101 nearest-miss: statics get hashable Python values."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("mode",))
def run(x, mode="fast"):
    return x * (2 if mode == "fast" else 3)


def caller(x):
    # static arg is a plain string; the traced arg is positional
    return run(jnp.asarray(x), mode="slow")
