# repro-lint: path=src/repro/sharding/fixture_rl203.py
"""RL203 nearest-miss: `jax.random` is NOT the stdlib module."""
from jax import random


def jitter(key, shape):
    return random.uniform(key, shape)
