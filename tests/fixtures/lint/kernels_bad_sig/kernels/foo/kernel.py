"""RL502: ref.py exists but mirrors nothing (renamed/reordered args)."""


def foo_kernel(x, scale, block_n=128, interpret=False):
    return x * scale
