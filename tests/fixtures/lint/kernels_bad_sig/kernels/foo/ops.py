"""Well-formed ops wrapper (keeps this tree RL502-only)."""
from .kernel import foo_kernel


def foo(x, scale, block_n=128, interpret=False):
    return foo_kernel(x, scale, block_n=block_n, interpret=interpret)
