"""Oracle whose public signature does NOT mirror the kernel entry."""


def reference_foo(scale, data):      # reordered + renamed: RL502
    return data * scale
