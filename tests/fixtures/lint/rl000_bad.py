"""RL000: a file the checkers cannot parse."""
def broken(:
    pass
