"""RL301: key consumed twice without split/fold_in — the refill-trace
bug class PR 5 fixed."""
import jax

key = jax.random.PRNGKey(0)
fill = jax.random.uniform(key, (8,))
refill = jax.random.normal(key, (8,))     # line 7: RL301


def per_step(key, steps):
    out = []
    for i in range(steps):
        out.append(jax.random.uniform(key, ()))  # line 13: RL301 (loop)
    return out
