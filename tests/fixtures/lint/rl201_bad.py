# repro-lint: path=src/repro/core/fixture_rl201.py
"""RL201: wall-clock read inside the deterministic core."""
import time


def stamp(result):
    return {"result": result, "at": time.time()}  # line 7: RL201
