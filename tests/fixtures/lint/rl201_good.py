# repro-lint: path=src/repro/launch/fixture_rl201.py
"""RL201 nearest-miss: the same wall-clock read in launch/ (allowed —
timing launchers is out of the deterministic core)."""
import time


def stamp(result):
    return {"result": result, "at": time.time()}
