"""RL102 nearest-miss: trace-safe Python predicates in a jitted fn."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flag",))
def run(x, rows=None, flag=False):
    if flag:                      # declared static
        x = x + 1
    if rows is None:              # pytree-structure dispatch: static
        rows = jnp.arange(x.shape[0])
    if x.ndim > 1:                # shape metadata: static on tracers
        x = x.sum(axis=-1)
    return x[rows]
