# repro-lint: path=src/repro/sharding/fixture_rl203.py
"""RL203: stdlib `random` in the deterministic core."""
import random


def jitter(xs):
    return [x + random.random() for x in xs]  # line 7: RL203
