"""RL601: axis-name literal not declared in sharding/axes.py."""
import jax
from jax.sharding import PartitionSpec as P

spec = P("confg")                            # line 5: RL601 (typo)
mesh = jax.make_mesh((1, 1), ("config", "trils"))  # line 6: RL601
