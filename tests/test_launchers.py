"""End-to-end launcher tests: training (with checkpoint/restart machinery)
and serving drivers on reduced configs."""
import jax
import numpy as np
import pytest

from repro.launch import serve as serve_launch
from repro.launch import train as train_launch


def test_train_launcher_loss_decreases(tmp_path):
    losses = train_launch.main([
        "--arch", "qwen3-1.7b", "--steps", "12", "--batch", "4",
        "--seq", "64", "--lr", "3e-3", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "6"])
    assert len(losses) == 12
    assert losses[-1] < losses[0]          # synthetic zipf is learnable


def test_train_launcher_resume(tmp_path):
    train_launch.main(["--arch", "qwen2-vl-2b", "--steps", "6",
                       "--batch", "2", "--seq", "32",
                       "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    losses = train_launch.main(["--arch", "qwen2-vl-2b", "--steps", "9",
                                "--batch", "2", "--seq", "32",
                                "--ckpt-dir", str(tmp_path),
                                "--ckpt-every", "3", "--resume"])
    assert len(losses) == 3                # resumed from step 6


def test_serve_launcher(capsys):
    stats = serve_launch.main(["--arch", "mamba2-2.7b", "--requests", "5",
                               "--slots", "2", "--max-new", "8",
                               "--prompt-len", "8", "--max-seq", "48"])
    assert stats["prefills"] == 5
    assert stats["tokens"] >= 5 * (8 + 7)   # prompt + decode tokens


def test_calibration_roundtrip(tmp_path):
    """Dry-run artifact → CostScale → throughput model still sane."""
    import json
    from repro.core import calibration, throughput as tp, projections as proj
    art = {"arch": "moonshot-v1-16b-a3b", "shape": "decode_32k",
           "mesh": "16x16", "n_devices": 256, "step": "decode",
           "flops_per_device": 2.9e9, "bytes_per_device": 1.3e11,
           "collective_bytes_per_device": 1.8e9,
           "batch": 128, "seq": 32768}
    m = tp.MoEModel("moonshot", 48, 2048, 64, 6, S=32768)
    scale = calibration.cost_scale_from_dryrun(art, m, "dec")
    assert all(s > 0 for s in scale)
    d = tp.Deployment(proj.VERA_RUBIN, 2026, 1)
    t_cal = float(tp.tps_request(m, d, scale=scale))
    t_raw = float(tp.tps_request(m, d))
    assert t_cal > 0 and t_raw > 0


def test_calibrated_scales_from_real_artifacts():
    """If the dry-run artifacts exist, calibration consumes them."""
    import os
    from repro.core import calibration, throughput as tp
    d = os.path.join(os.path.dirname(__file__), "..", "experiments",
                     "dryrun")
    if not os.path.isdir(d) or not os.listdir(d):
        pytest.skip("no dry-run artifacts")
    scales = calibration.calibrated_scales(d, tp.MODELS["MoE-0.6T"],
                                           step="decode")
    assert scales  # at least one decode cell
    for s in scales.values():
        assert s.compute > 0 and s.memory > 0
