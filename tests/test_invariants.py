"""Conservation/ordering invariants of the placement engine.

For every reference design: placing a mixed trace and then releasing
100% of everything placed must restore `init_state` exactly (power,
air, liquid, tiles, line-up loads), and the load ordering
`lineup_tot >= lineup_ha >= 0` must hold after every step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import arrivals, hierarchy as h, placement as pl

DESIGN_NAMES = ("4N/3", "3+1", "10N/8", "8+2")

# jitted once per topology shape (4N/3 and 3+1 share one executable)
_PLACE = jax.jit(pl.place)


def _mixed_trace(n_events=28, seed=11):
    # pods + LA tier + clusters: exercises every release path
    return arrivals.sample_mixed_trace(
        n_events, year=2028, seed=seed, pod_racks=3, quantum_racks=4,
        la_fraction=0.3)


def _place_trace(jt, state, trace, policy=pl.POLICY_VAR_MIN, seed=0,
                 check=None):
    key = jax.random.PRNGKey(seed)
    rows, counts, placed = [], [], []
    for i in range(len(trace)):
        dep = pl.Deployment.make(
            float(trace.rack_kw[i]), int(trace.n_racks[i]),
            is_gpu=bool(trace.is_gpu[i]), tier=int(trace.tier[i]),
            is_pod=bool(trace.is_pod[i]))
        state, ok, r, c = _PLACE(jt, state, dep, policy,
                                 jax.random.fold_in(key, i))
        rows.append(r)
        counts.append(c)
        placed.append(bool(ok))
        if check is not None:
            check(state)
    return state, jnp.stack(rows), jnp.stack(counts), np.asarray(placed)


@pytest.mark.parametrize("name", DESIGN_NAMES)
def test_place_release_restores_init_state(name):
    topo = h.build_topology(h.get_design(name))
    jt = pl.jax_topology(topo)
    st0 = pl.init_state(topo)
    trace = _mixed_trace()

    state, rows, counts, placed = _place_trace(jt, st0, trace)
    assert placed.any(), "trace placed nothing; test is vacuous"

    frac = jnp.asarray(placed, jnp.float32)        # release 100% of placed
    state = pl.release_bulk(jt, state, rows, counts,
                            jnp.asarray(trace.rack_kw),
                            jnp.asarray(trace.is_gpu),
                            jnp.asarray(trace.tier), frac)

    # conservation: all loads return to ≈ 0 (f32 accumulation noise only)
    np.testing.assert_allclose(np.asarray(state.row_load),
                               np.asarray(st0.row_load), atol=0.5)
    np.testing.assert_allclose(np.asarray(state.lineup_ha),
                               np.asarray(st0.lineup_ha), atol=0.05)
    np.testing.assert_allclose(np.asarray(state.lineup_tot),
                               np.asarray(st0.lineup_tot), atol=0.05)
    np.testing.assert_allclose(np.asarray(state.hall_liq),
                               np.asarray(st0.hall_liq), atol=0.05)


@pytest.mark.parametrize("name", DESIGN_NAMES)
def test_lineup_load_ordering_along_trace(name):
    topo = h.build_topology(h.get_design(name))
    jt = pl.jax_topology(topo)
    trace = _mixed_trace(seed=23)

    def check(state):
        ha = np.asarray(state.lineup_ha)
        tot = np.asarray(state.lineup_tot)
        assert (ha >= -1e-3).all()
        assert (tot >= ha - 1e-3).all()

    _place_trace(jt, pl.init_state(topo), trace, seed=1, check=check)


def test_partial_release_is_linear():
    """Releasing fraction f then (1-f) equals releasing 1.0 outright."""
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st0 = pl.init_state(topo)
    trace = _mixed_trace(n_events=10, seed=5)
    state, rows, counts, placed = _place_trace(jt, st0, trace)

    kw = jnp.asarray(trace.rack_kw)
    gpu = jnp.asarray(trace.is_gpu)
    tier = jnp.asarray(trace.tier)
    f = 0.35 * jnp.asarray(placed, jnp.float32)
    rest = (1.0 - 0.35) * jnp.asarray(placed, jnp.float32)
    two_step = pl.release_bulk(jt, state, rows, counts, kw, gpu, tier, f)
    two_step = pl.release_bulk(jt, two_step, rows, counts, kw, gpu, tier,
                               rest)
    one_step = pl.release_bulk(jt, state, rows, counts, kw, gpu, tier,
                               jnp.asarray(placed, jnp.float32))
    for a, b in zip(jax.tree.leaves(two_step), jax.tree.leaves(one_step)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.1)
