"""Distribution tests on an 8-device host mesh (pod=2 × data=2 × model=2).

These must force the device count BEFORE jax initializes; when the full
suite runs in one process jax may already be initialized with 1 device —
then the mesh tests skip (they are fully covered by the standalone run
and by the 512-device dry-run)."""
import os
import sys

if "jax" not in sys.modules:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import get_smoke_config  # noqa: E402
from repro.models.api import build_model  # noqa: E402
from repro.optim import adamw  # noqa: E402
from repro.runtime import elastic  # noqa: E402
from repro.sharding import axes as ax  # noqa: E402
from repro.train.step import make_train_step  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

needs_devices = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 host devices")


def _mesh():
    return jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         devices=jax.devices()[:8])


class TestRules:
    def test_spec_for_dedups_axes(self):
        rules = ax.base_rules(multi_pod=True)
        spec = ax.spec_for(("batch", "heads"), rules)
        assert spec == P(("pod", "data"), "model")

    def test_divisible_spec_drops_nondivisible(self):
        mesh = _mesh() if jax.device_count() >= 8 else None
        if mesh is None:
            pytest.skip("needs devices")
        spec = ax.divisible_spec(P("model"), (3,), mesh)
        assert spec == P()
        spec = ax.divisible_spec(P(("pod", "data")), (2,), mesh)
        assert spec == P("pod")          # shrinks to divisible prefix
        spec = ax.divisible_spec(P("model", None, "data"), (4, 5, 6), mesh)
        assert spec == P("model", None, "data")

    def test_fsdp_and_opt_rules(self):
        r = ax.base_rules(True)
        fr = ax.fsdp_rules(r, True)
        assert fr["embed"] == ("pod", "data")
        orr = ax.opt_rules(r, False)
        assert orr["embed"] == ("data",)


@needs_devices
class TestMeshExecution:
    def test_sharded_train_step_runs(self):
        cfg = get_smoke_config("granite-moe-1b-a400m")
        model = build_model(cfg)
        mesh = _mesh()
        rules = ax.base_rules(multi_pod=True)
        with ax.use_rules(rules, mesh):
            params = model.init(jax.random.PRNGKey(0))
            p_axes = model.param_axes()
            shardings = ax.tree_shardings_matched(p_axes, params, mesh,
                                                  rules)
            params = jax.tree.map(jax.device_put, params, shardings)
            opt_state = adamw.init(params)
            step = jax.jit(make_train_step(model, adamw.AdamWConfig()))
            batch = {"tokens": jax.random.randint(
                jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)}
            batch = jax.device_put(batch, {
                "tokens": jax.NamedSharding(
                    mesh, P(("pod", "data")))})
            with mesh:
                params2, opt2, metrics = step(params, opt_state, batch)
        assert np.isfinite(float(metrics["loss"]))

    def test_sharded_vs_single_device_loss_matches(self):
        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)}
        loss_1dev, _ = jax.jit(model.loss)(params, batch)

        mesh = _mesh()
        rules = ax.base_rules(multi_pod=True)
        with ax.use_rules(rules, mesh):
            p_sh = ax.tree_shardings_matched(model.param_axes(), params,
                                             mesh, rules)
            params_s = jax.tree.map(jax.device_put, params, p_sh)
            with mesh:
                loss_shard, _ = jax.jit(model.loss)(params_s, batch)
        np.testing.assert_allclose(float(loss_1dev), float(loss_shard),
                                   rtol=2e-2)

    def test_elastic_reshard_after_device_loss(self):
        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        mesh8 = _mesh()
        rules = ax.base_rules(multi_pod=True)
        p_axes = model.param_axes()
        params8 = elastic.reshard(params, p_axes, mesh8, rules)
        # lose 4 devices → resume on a 1×2×2 mesh
        mesh4 = elastic.survivors_mesh([1, 3, 5, 7], (1, 2, 2),
                                       ("pod", "data", "model"))
        params4 = elastic.reshard(params8, p_axes, mesh4, rules)
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)}
        with ax.use_rules(rules, mesh4), mesh4:
            loss, _ = jax.jit(model.loss)(params4, batch)
        assert np.isfinite(float(loss))

    def test_compressed_psum_shard_map(self):
        from jax import shard_map
        from repro.optim.compression import compressed_psum
        mesh = _mesh()
        x = jnp.arange(32.0).reshape(8, 4) / 31.0

        f = shard_map(lambda xs: compressed_psum(xs, "pod"),
                      mesh=mesh, in_specs=P("pod"), out_specs=P("pod"))
        out = f(x)
        # psum over pod axis of the two shards
        ref = jnp.concatenate([x[:4] + x[4:]] * 2, axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=0.02)
