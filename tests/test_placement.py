"""Placement-engine behaviour: the paper's §3 worked examples."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as h
from repro.core import placement as pl
from repro.core.resources import TIER_HA, TIER_LA


def _uniform_state(topo, load_kw):
    st = pl.init_state(topo)
    X = topo.lineup_cap.shape[0]
    return st._replace(lineup_ha=jnp.full((X,), load_kw),
                       lineup_tot=jnp.full((X,), load_kw))


class TestReserveFragmentation:
    """§3.2: 10N/8, 18 MW uniform, 650 kW rack with k=4 feeds."""

    def setup_method(self, _):
        self.topo = h.build_topology(h.design_10n8())
        self.jt = pl.jax_topology(self.topo)

    def test_rejects_despite_aggregate_slack(self):
        st = _uniform_state(self.topo, 1800.0)   # 2 MW aggregate headroom
        dep = pl.Deployment.make(650.0, 1, is_gpu=True)
        assert not bool(pl.row_feasible(self.jt, st, dep, 1).any())

    def test_admits_below_threshold(self):
        # headroom 220 kW > Δ = 650/3 ≈ 216.7 kW
        st = _uniform_state(self.topo, 1780.0)
        dep = pl.Deployment.make(650.0, 1, is_gpu=True)
        assert bool(pl.row_feasible(self.jt, st, dep, 1).any())

    def test_la_rack_consumes_reserve(self):
        st = _uniform_state(self.topo, 1800.0)
        dep = pl.Deployment.make(650.0, 1, is_gpu=True, tier=TIER_LA)
        assert bool(pl.row_feasible(self.jt, st, dep, 1).any())


class TestBlockQuantization:
    """§3.3: block admits ⌊C/P⌋ deployments per line-up (Eq. 2)."""

    @pytest.mark.parametrize("kw,per_lineup", [(800.0, 3), (1300.0, 1),
                                               (600.0, 4)])
    def test_floor_capacity(self, kw, per_lineup):
        topo = h.build_topology(h.design_3p1())
        jt = pl.jax_topology(topo)
        st = pl.init_state(topo)
        dep = pl.Deployment.make(kw, 1, is_gpu=True)
        key = jax.random.PRNGKey(0)
        n = 0
        for i in range(20):
            st, ok, _, _ = pl.place(jt, st, dep, pl.POLICY_VAR_MIN,
                                    jax.random.fold_in(key, i))
            if not bool(ok):
                break
            n += 1
        assert n == 3 * per_lineup   # 3 active line-ups


def test_release_restores_state():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st0 = pl.init_state(topo)
    dep = pl.Deployment.make(120.0, 5, is_gpu=False)
    st1, ok, rows, counts = pl.place(jt, st0, dep, pl.POLICY_VAR_MIN,
                                     jax.random.PRNGKey(0))
    assert bool(ok)
    st2 = pl.release_bulk(jt, st1, rows[None], counts[None],
                          jnp.asarray([120.0]), jnp.asarray([False]),
                          jnp.asarray([0]), jnp.asarray([1.0]))
    for a, b in zip(jax.tree.leaves(st0._replace(rr_cursor=st2.rr_cursor)),
                    jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_pod_atomic_and_same_domain():
    topo = h.build_topology(h.design_10n8())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    dep = pl.Deployment.make(600.0, 5, is_gpu=True, is_pod=True)
    st, ok, rows, counts = pl.place(jt, st, dep, pl.POLICY_VAR_MIN,
                                    jax.random.PRNGKey(1))
    assert bool(ok)
    rows = np.asarray(rows)
    doms = np.asarray(topo.row_domain)[rows[rows >= 0]]
    assert len(set(doms.tolist())) == 1
    assert float(np.asarray(counts).sum()) == 5.0


def test_gpu_only_in_hd_rows():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    dep = pl.Deployment.make(200.0, 1, is_gpu=True)
    feas = pl.row_feasible(jt, st, dep, 1)
    assert not bool((np.asarray(feas) & ~topo.row_is_hd).any())


def test_never_exceeds_capacity_under_any_sequence():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    key = jax.random.PRNGKey(2)
    rng = np.random.default_rng(0)
    for i in range(120):
        kw = float(rng.uniform(10, 400))
        gpu = bool(rng.random() < 0.4)
        dep = pl.Deployment.make(kw, int(rng.integers(1, 6)), is_gpu=gpu)
        st, ok, _, _ = pl.place(jt, st, dep, int(rng.integers(0, 4)),
                                jax.random.fold_in(key, i))
    assert bool((np.asarray(st.row_load) <=
                 np.asarray(topo.row_cap) + 1e-2).all())
    eff = topo.design.ha_frac * np.asarray(topo.lineup_cap)
    assert bool((np.asarray(st.lineup_ha) <= eff + 1e-2).all())
