"""Placement-engine behaviour: the paper's §3 worked examples."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as h
from repro.core import placement as pl
from repro.core.resources import TIER_HA, TIER_LA


def _uniform_state(topo, load_kw):
    st = pl.init_state(topo)
    X = topo.lineup_cap.shape[0]
    return st._replace(lineup_ha=jnp.full((X,), load_kw),
                       lineup_tot=jnp.full((X,), load_kw))


class TestReserveFragmentation:
    """§3.2: 10N/8, 18 MW uniform, 650 kW rack with k=4 feeds."""

    def setup_method(self, _):
        self.topo = h.build_topology(h.design_10n8())
        self.jt = pl.jax_topology(self.topo)

    def test_rejects_despite_aggregate_slack(self):
        st = _uniform_state(self.topo, 1800.0)   # 2 MW aggregate headroom
        dep = pl.Deployment.make(650.0, 1, is_gpu=True)
        assert not bool(pl.row_feasible(self.jt, st, dep, 1).any())

    def test_admits_below_threshold(self):
        # headroom 220 kW > Δ = 650/3 ≈ 216.7 kW
        st = _uniform_state(self.topo, 1780.0)
        dep = pl.Deployment.make(650.0, 1, is_gpu=True)
        assert bool(pl.row_feasible(self.jt, st, dep, 1).any())

    def test_la_rack_consumes_reserve(self):
        st = _uniform_state(self.topo, 1800.0)
        dep = pl.Deployment.make(650.0, 1, is_gpu=True, tier=TIER_LA)
        assert bool(pl.row_feasible(self.jt, st, dep, 1).any())


class TestBlockQuantization:
    """§3.3: block admits ⌊C/P⌋ deployments per line-up (Eq. 2)."""

    @pytest.mark.parametrize("kw,per_lineup", [(800.0, 3), (1300.0, 1),
                                               (600.0, 4)])
    def test_floor_capacity(self, kw, per_lineup):
        topo = h.build_topology(h.design_3p1())
        jt = pl.jax_topology(topo)
        st = pl.init_state(topo)
        dep = pl.Deployment.make(kw, 1, is_gpu=True)
        key = jax.random.PRNGKey(0)
        n = 0
        for i in range(20):
            st, ok, _, _ = pl.place(jt, st, dep, pl.POLICY_VAR_MIN,
                                    jax.random.fold_in(key, i))
            if not bool(ok):
                break
            n += 1
        assert n == 3 * per_lineup   # 3 active line-ups


def test_release_restores_state():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st0 = pl.init_state(topo)
    dep = pl.Deployment.make(120.0, 5, is_gpu=False)
    st1, ok, rows, counts = pl.place(jt, st0, dep, pl.POLICY_VAR_MIN,
                                     jax.random.PRNGKey(0))
    assert bool(ok)
    st2 = pl.release_bulk(jt, st1, rows[None], counts[None],
                          jnp.asarray([120.0]), jnp.asarray([False]),
                          jnp.asarray([0]), jnp.asarray([1.0]))
    for a, b in zip(jax.tree.leaves(st0._replace(rr_cursor=st2.rr_cursor)),
                    jax.tree.leaves(st2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_pod_atomic_and_same_domain():
    topo = h.build_topology(h.design_10n8())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    dep = pl.Deployment.make(600.0, 5, is_gpu=True, is_pod=True)
    st, ok, rows, counts = pl.place(jt, st, dep, pl.POLICY_VAR_MIN,
                                    jax.random.PRNGKey(1))
    assert bool(ok)
    rows = np.asarray(rows)
    doms = np.asarray(topo.row_domain)[rows[rows >= 0]]
    assert len(set(doms.tolist())) == 1
    assert float(np.asarray(counts).sum()) == 5.0


def test_gpu_only_in_hd_rows():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    dep = pl.Deployment.make(200.0, 1, is_gpu=True)
    feas = pl.row_feasible(jt, st, dep, 1)
    assert not bool((np.asarray(feas) & ~topo.row_is_hd).any())


def test_hall_stranding_uneven_lineup_padding():
    """`hall_stranding` must bin line-ups by the topology's real
    line-up→hall map.  A 2-hall topology with 3 + 4 line-ups (7 total,
    not divisible by 2) used to be binned `arange(7) // 3` =
    [0,0,0,1,1,1,2] — hall ids beyond H silently dropped from the
    segment sum, mis-attributing the last line-up's capacity and load."""
    cap = np.array([2500.0, 2500.0, 2500.0, 2000.0, 2000.0, 2000.0, 2000.0],
                   np.float32)
    active = np.array([True, True, False, True, True, True, True])
    lineup_hall = np.array([0, 0, 0, 1, 1, 1, 1], np.int32)
    ha_frac = 0.75
    jt = pl.JaxTopology(
        row_cap=jnp.zeros((2, 4)), row_feeds=jnp.zeros((2, 4), jnp.int32),
        row_nfeeds=jnp.zeros((2,), jnp.int32),
        row_is_hd=jnp.zeros((2,), bool),
        row_domain=jnp.zeros((2,), jnp.int32),
        row_hall=jnp.asarray([0, 1], jnp.int32),
        hd_index=jnp.asarray([0, 1], jnp.int32),
        lineup_cap=jnp.asarray(cap),
        lineup_is_active=jnp.asarray(active),
        lineup_hall=jnp.asarray(lineup_hall),
        hall_liq_cap=jnp.zeros((2,)),
        ha_frac=jnp.asarray(ha_frac, jnp.float32),
        is_block=jnp.asarray(False))
    ha = np.array([500.0, 1200.0, 300.0, 900.0, 0.0, 1500.0, 1400.0],
                  np.float32)
    state = pl.init_state_from(jt)._replace(lineup_ha=jnp.asarray(ha))

    got = np.asarray(pl.hall_stranding(jt, state))
    eff = ha_frac * cap * active
    load = ha * active
    want = np.array([
        np.clip((eff[h].sum() - load[h].sum())
                / max(eff[h].sum(), 1.0), 0.0, 1.0)
        for h in (lineup_hall == 0, lineup_hall == 1)])
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_built_topology_lineup_hall_map():
    """`build_topology` tiles line-ups per hall, so the stored map must be
    the per-hall block layout (incl. sweep padding)."""
    topo = h.build_topology(h.design_3p1(), n_halls=3, lineups_per_hall=6)
    X = topo.lineup_cap.shape[0]
    np.testing.assert_array_equal(topo.lineup_hall,
                                  np.arange(X) // topo.lineups_per_hall)
    jt = pl.jax_topology(topo)
    np.testing.assert_array_equal(np.asarray(jt.lineup_hall),
                                  topo.lineup_hall)
    # hd_index: HD rows first, ascending, then the rest
    hd = np.asarray(topo.row_is_hd)
    idx = np.asarray(jt.hd_index)
    n_hd = int(hd.sum())
    assert topo.n_hd_rows == n_hd
    np.testing.assert_array_equal(idx[:n_hd], np.flatnonzero(hd))
    np.testing.assert_array_equal(np.sort(idx), np.arange(hd.shape[0]))


def test_compacted_pod_scan_matches_full():
    """`_place_pod` over the HD-compacted row view is bitwise the full-row
    scan (GPU pods are HD-only, so the subset covers every feasible
    row) — across all four policies."""
    topo = h.build_topology(h.design_10n8())
    jt = pl.jax_topology(topo)
    dep = pl.Deployment.make(600.0, 5, is_gpu=True, is_pod=True)
    active = jnp.ones((topo.row_cap.shape[0],), bool)
    for policy in range(4):
        st = pl.init_state(topo)
        key = jax.random.PRNGKey(7 + policy)
        for i in range(6):
            k = jax.random.fold_in(key, i)
            st_f, ok_f, rows_f, counts_f = pl._place_pod(
                jt, st, dep, policy, k, active)
            st_c, ok_c, rows_c, counts_c = pl._place_pod(
                jt, st, dep, policy, k, active, hd_scan=topo.n_hd_rows)
            assert bool(ok_f) == bool(ok_c)
            np.testing.assert_array_equal(np.asarray(rows_f),
                                          np.asarray(rows_c))
            np.testing.assert_array_equal(np.asarray(counts_f),
                                          np.asarray(counts_c))
            for a, b in zip(jax.tree.leaves(st_f), jax.tree.leaves(st_c)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            st = st_c


def test_never_exceeds_capacity_under_any_sequence():
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    key = jax.random.PRNGKey(2)
    rng = np.random.default_rng(0)
    for i in range(120):
        kw = float(rng.uniform(10, 400))
        gpu = bool(rng.random() < 0.4)
        dep = pl.Deployment.make(kw, int(rng.integers(1, 6)), is_gpu=gpu)
        st, ok, _, _ = pl.place(jt, st, dep, int(rng.integers(0, 4)),
                                jax.random.fold_in(key, i))
    assert bool((np.asarray(st.row_load) <=
                 np.asarray(topo.row_cap) + 1e-2).all())
    eff = topo.design.ha_frac * np.asarray(topo.lineup_cap)
    assert bool((np.asarray(st.lineup_ha) <= eff + 1e-2).all())
