"""Per-architecture smoke tests (assignment requirement): reduced
same-family configs, one forward/train step on CPU, output shapes + no
NaNs; prefill/decode consistency with the training forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models.api import build_model
from repro.models import lm

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(KEY, (B, 32, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(KEY, (B, 12), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
                "vision_embeds": jax.random.normal(
                    KEY, (B, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    loss, metrics = jax.jit(model.loss)(params, _batch(cfg))
    assert np.isfinite(float(loss))
    assert float(metrics["tokens"]) > 0
    # one gradient step
    grads = jax.grad(lambda p: model.loss(p, _batch(cfg))[0])(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_serve_path(arch_id):
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, caches = jax.jit(
        lambda p, b: model.prefill(p, b, 48))(params, batch)
    assert np.isfinite(np.asarray(logits)).all()
    if cfg.family == "audio":
        pos = batch["tokens"].shape[1]
    elif cfg.family == "vlm":
        pos = batch["tokens"].shape[1] + cfg.frontend_seq
    else:
        pos = batch["tokens"].shape[1]
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches = jax.jit(model.decode_step)(
        params, tok, jnp.asarray(pos, jnp.int32), caches)
    assert np.isfinite(np.asarray(logits2)).all()
    assert logits2.shape == (2, cfg.vocab)


@pytest.mark.parametrize("arch_id", ["qwen3-1.7b", "mamba2-2.7b",
                                     "jamba-1.5-large-398b",
                                     "granite-moe-1b-a400m"])
def test_prefill_matches_train_forward(arch_id):
    """The serving prefill logits at the last prompt position must match
    the training-mode forward (same parameters, same tokens)."""
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    full_logits, _ = lm.forward_train(cfg, params, tokens)
    pre_logits, _, _ = lm.prefill(cfg, params, tokens, 32)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, -1]), np.asarray(pre_logits),
        atol=0.15, rtol=0.05)   # bf16 accumulation-order tolerance


@pytest.mark.parametrize("arch_id", ["qwen3-1.7b", "mamba2-2.7b"])
def test_decode_matches_teacher_forcing(arch_id):
    """Decoding token-by-token reproduces the teacher-forced forward."""
    cfg = get_smoke_config(arch_id)
    model = build_model(cfg)
    params = model.init(KEY)
    T = 12
    tokens = jax.random.randint(KEY, (1, T), 0, cfg.vocab)
    full_logits, _ = lm.forward_train(cfg, params, tokens)
    _, caches, _ = lm.prefill(cfg, params, tokens[:, :4], 24)
    outs = []
    for t in range(4, T):
        lg, caches = lm.decode_step(cfg, params, tokens[:, t:t + 1],
                                    jnp.asarray(t, jnp.int32), caches)
        outs.append(np.asarray(lg))
    # full_logits[t] predicts token t+1 — compare distributions argmax
    for i, t in enumerate(range(4, T)):
        np.testing.assert_allclose(outs[i][0], np.asarray(full_logits[0, t]),
                                   atol=0.25, rtol=0.1)


def test_full_configs_match_assignment():
    """Exact published parameters (the full configs are exercised via the
    dry-run only — never materialized here)."""
    c = get_config("moonshot-v1-16b-a3b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (48, 2048, 16, 16)
    assert (c.d_ff, c.vocab, c.n_experts, c.top_k) == (1408, 163840, 64, 6)
    c = get_config("granite-moe-1b-a400m")
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (24, 1024, 32, 8)
    c = get_config("qwen3-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (40, 5120, 40, 8, 17408)
    assert c.qk_norm
    c = get_config("nemotron-4-15b")
    assert c.act == "sq_relu" and c.vocab == 256000
    c = get_config("qwen2-vl-2b")
    assert c.mrope_sections == (16, 24, 24) and c.n_kv_heads == 2
    c = get_config("jamba-1.5-large-398b")
    assert (c.attn_period, c.moe_period, c.n_experts, c.top_k) == (8, 2, 16, 2)
    assert c.n_layers == 72 and c.d_model == 8192
    c = get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (64, 2560, 128)
    c = get_config("whisper-small")
    assert (c.n_enc_layers, c.n_layers, c.d_model, c.vocab) == \
        (12, 12, 768, 51865)
