"""Kernel-path placement ≡ jnp oracle (the bitwise harness for the
`use_kernel` dispatch in `core.placement`).

The jnp path is the ground truth; the Pallas kernel (run here in
interpret mode — CPU CI) must reproduce feasibility masks bitwise,
variance scores bitwise at feasible rows, and therefore chosen rows,
state updates and stranding outputs bitwise, across policies,
deployment kinds, row subsets and saturation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hierarchy as h
from repro.core import placement as pl
from repro.core.resources import TIER_HA, TIER_LA

KEY = jax.random.PRNGKey(11)


def _assert_states_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _busy_state(jt, topo, seed, n_events=12):
    """A part-filled hall state (jnp path) so feasibility is non-trivial."""
    st = pl.init_state(topo)
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    for i in range(n_events):
        dep = pl.Deployment.make(float(rng.uniform(100, 500)),
                                 int(rng.integers(1, 4)),
                                 is_gpu=bool(rng.random() < 0.5),
                                 tier=int(rng.random() < 0.3))
        st, _, _, _ = pl.place(jt, st, dep, int(rng.integers(0, 4)),
                               jax.random.fold_in(key, i))
    return st


DESIGNS = [h.design_4n3(), h.design_3p1()]   # distributed + block family


@pytest.mark.parametrize("design", DESIGNS, ids=["4N/3", "3+1"])
@pytest.mark.parametrize("tier", [TIER_HA, TIER_LA], ids=["HA", "LA"])
def test_row_feasible_and_scores_bitwise(design, tier):
    topo = h.build_topology(design)
    jt = pl.jax_topology(topo)
    st = _busy_state(jt, topo, seed=3)
    dep = pl.Deployment.make(350.0, 2, is_gpu=False, tier=tier)
    key = jax.random.fold_in(KEY, tier)
    f_j = pl.row_feasible(jt, st, dep, 2)
    f_k = pl.row_feasible(jt, st, dep, 2, use_kernel=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(f_j), np.asarray(f_k))
    s_j = pl.row_scores(jt, st, dep, 2, pl.POLICY_VAR_MIN, key)
    s_k = pl.row_scores(jt, st, dep, 2, pl.POLICY_VAR_MIN, key,
                        use_kernel=True, interpret=True)
    feas = np.asarray(f_j)
    # raw variance scores differ only by feed-sum association (f32 ulps);
    # infeasible rows carry the kernel's BIG mask and never survive
    # place_in_row's argmin masking — decisions/states are bitwise below
    np.testing.assert_allclose(np.asarray(s_j)[feas],
                               np.asarray(s_k)[feas], rtol=1e-6)


@pytest.mark.parametrize("design", DESIGNS, ids=["4N/3", "3+1"])
@pytest.mark.parametrize("policy", range(4), ids=pl.POLICY_NAMES)
def test_place_in_row_bitwise_across_policies(design, policy):
    """Chosen row, ok flag and every state leaf identical for cluster
    placements under all four policies, both families."""
    topo = h.build_topology(design)
    jt = pl.jax_topology(topo)
    active = jnp.ones((topo.row_cap.shape[0],), bool)
    st = _busy_state(jt, topo, seed=policy)
    for i in range(4):
        k = jax.random.fold_in(KEY, 10 * policy + i)
        dep = pl.Deployment.make(200.0 + 90.0 * i, 1 + i % 3,
                                 is_gpu=(i % 2 == 0), tier=i % 2)
        st_j, ok_j, row_j = pl.place_in_row(jt, st, dep, dep.n_racks,
                                            policy, k, active)
        st_k, ok_k, row_k = pl.place_in_row(jt, st, dep, dep.n_racks,
                                            policy, k, active,
                                            use_kernel=True, interpret=True)
        assert bool(ok_j) == bool(ok_k)
        assert int(row_j) == int(row_k)
        _assert_states_equal(st_j, st_k)
        st = st_k


@pytest.mark.parametrize("policy", range(4), ids=pl.POLICY_NAMES)
def test_pod_scan_kernel_bitwise(policy):
    """`_place_pod` (multi-row pod, domain locking) with the kernel path:
    full-R scan and the HD-compacted subset both bitwise vs jnp."""
    topo = h.build_topology(h.design_10n8())
    jt = pl.jax_topology(topo)
    dep = pl.Deployment.make(600.0, 5, is_gpu=True, is_pod=True)
    active = jnp.ones((topo.row_cap.shape[0],), bool)
    st = pl.init_state(topo)
    for i in range(4):
        k = jax.random.fold_in(jax.random.PRNGKey(7 + policy), i)
        ref = pl._place_pod(jt, st, dep, policy, k, active)
        for hd_scan in (None, topo.n_hd_rows):
            got = pl._place_pod(jt, st, dep, policy, k, active,
                                hd_scan=hd_scan, use_kernel=True,
                                interpret=True)
            assert bool(ref[1]) == bool(got[1])
            np.testing.assert_array_equal(np.asarray(ref[2]),
                                          np.asarray(got[2]))
            np.testing.assert_array_equal(np.asarray(ref[3]),
                                          np.asarray(got[3]))
            _assert_states_equal(ref[0], got[0])
        st = ref[0]


def test_uneven_block_r_remainder():
    """Engine-level padding: a topology whose row count is not a multiple
    of `block_r` exercises the kernel's remainder tile; padded rows are
    masked infeasible and sliced off."""
    topo = h.build_topology(h.design_10n8())   # R = 20 rows
    jt = pl.jax_topology(topo)
    R = topo.row_cap.shape[0]
    assert R % 8 != 0 or R % 16 != 0   # at least one uneven tiling below
    st = _busy_state(jt, topo, seed=5)
    dep = pl.Deployment.make(420.0, 1, is_gpu=True)
    f_ref = pl.row_feasible(jt, st, dep, 1)
    s_ref = pl.row_scores(jt, st, dep, 1, pl.POLICY_VAR_MIN, KEY)
    feas = np.asarray(f_ref)
    extra = np.asarray(pl._row_fits(jt, st, dep, 1))
    outs = {}
    for block_r in (8, 16, 128):
        f_k, v_k = pl._kernel_feas_scores(jt, st, dep, 1, interpret=True,
                                          block_r=block_r)
        assert f_k.shape == v_k.shape == (R,)
        np.testing.assert_array_equal(feas, np.asarray(f_k) & extra)
        # vs jnp: feed-sum association only (f32 ulps)
        np.testing.assert_allclose(np.asarray(s_ref)[feas],
                                   np.asarray(v_k)[feas], rtol=1e-6)
        outs[block_r] = (np.asarray(f_k), np.asarray(v_k))
    # padding must be invisible: every tiling bitwise-identical
    for block_r in (8, 16):
        np.testing.assert_array_equal(outs[block_r][0], outs[128][0])
        np.testing.assert_array_equal(outs[block_r][1], outs[128][1])


def test_all_infeasible_rows():
    """A deployment nothing can host: both paths refuse identically and
    leave the state untouched (the BIG-masked argmin never 'places')."""
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    st = pl.init_state(topo)
    dep = pl.Deployment.make(10_000.0, 8, is_gpu=True)   # overflows any row
    active = jnp.ones((topo.row_cap.shape[0],), bool)
    st_j, ok_j, row_j = pl.place_in_row(jt, st, dep, dep.n_racks,
                                        pl.POLICY_VAR_MIN, KEY, active)
    st_k, ok_k, row_k = pl.place_in_row(jt, st, dep, dep.n_racks,
                                        pl.POLICY_VAR_MIN, KEY, active,
                                        use_kernel=True, interpret=True)
    assert not bool(ok_j) and not bool(ok_k)
    assert int(row_j) == int(row_k) == -1
    _assert_states_equal(st_j, st)
    _assert_states_equal(st_k, st)
    assert not bool(np.asarray(
        pl.row_feasible(jt, st, dep, dep.n_racks, use_kernel=True,
                        interpret=True)).any())


def test_run_trial_kernel_end_to_end():
    """Whole-trial equivalence: `run_trial(use_kernel=True,
    interpret=True)` bitwise vs the jnp path — states, placements and
    stranding outputs — on fill → harvest → refill."""
    from repro.core import arrivals
    from repro.core.singlehall import TraceArrays, run_trial
    topo = h.build_topology(h.design_4n3())
    jt = pl.jax_topology(topo)
    tr_a = arrivals.sample_mixed_traces(2, 50, year=2028, seed=0)
    tr_b = arrivals.sample_mixed_traces(2, 30, year=2028, seed=0, phase=1)
    for t in range(2):
        t_a = TraceArrays.from_trace(tr_a.trial(t))
        t_b = TraceArrays.from_trace(tr_b.trial(t))
        key = jax.random.fold_in(KEY, t)
        out_j = run_trial(jt, pl.init_state(topo), t_a, t_b,
                          pl.POLICY_VAR_MIN, key)
        out_k = run_trial(jt, pl.init_state(topo), t_a, t_b,
                          pl.POLICY_VAR_MIN, key, use_kernel=True,
                          kernel_interpret=True)
        _assert_states_equal(out_j[0], out_k[0])
        for res_j, res_k in zip(out_j[1:], out_k[1:]):
            np.testing.assert_array_equal(np.asarray(res_j.placed),
                                          np.asarray(res_k.placed))
            np.testing.assert_array_equal(np.asarray(res_j.rows),
                                          np.asarray(res_k.rows))
        np.testing.assert_array_equal(
            np.asarray(pl.lineup_stranding(jt, out_j[0])),
            np.asarray(pl.lineup_stranding(jt, out_k[0])))
        np.testing.assert_array_equal(
            np.asarray(pl.hall_stranding(jt, out_j[0])),
            np.asarray(pl.hall_stranding(jt, out_k[0])))


def test_mc_sweep_kernel_end_to_end():
    """Small MC grid (pods → split-trace + HD-compacted scan) through
    `mc_sweep(use_kernel=True, kernel_interpret=True)`: every output
    column bitwise vs the jnp path."""
    from repro.core.mc_sweep import MCAxes, mc_sweep
    axes = MCAxes.zip(designs=[h.design_4n3()], policies=[0, 3], seeds=[0])
    kw = dict(n_trials=2, n_events=40, pod_racks=3, models=())
    a = mc_sweep(axes, **kw)
    b = mc_sweep(axes, use_kernel=True, kernel_interpret=True, **kw)
    for name in ("lineup_stranding", "hall_stranding", "deployed_kw",
                 "saturated", "placed_a", "placed_b"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


def test_fleet_sweep_kernel_end_to_end():
    """Fleet lifecycle through `sweep(use_kernel=True,
    kernel_interpret=True)`: stranding trajectories and hall counts
    bitwise vs the jnp path on a small 2-config grid."""
    from repro.core.arrivals import EnvelopeSpec
    from repro.core.sweep import SweepAxes, sweep
    env = EnvelopeSpec(start_year=2026, end_year=2027, gpu_gw=0.004,
                       compute_gw=0.002, storage_gw=0.0)
    axes = SweepAxes.zip(designs=[h.design_4n3(), h.design_3p1()],
                         envs=[env])
    a = sweep(axes, models=())
    b = sweep(axes, models=(), use_kernel=True, kernel_interpret=True)
    for name in ("halls_active", "deployed_mw", "p50_stranding",
                 "p90_stranding", "final_hall_stranding",
                 "final_lineup_stranding", "n_halls_built",
                 "placed_fraction"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)
