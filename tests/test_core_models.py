"""Hierarchy, cost, projections, throughput, arrivals — paper §2/§5."""
import numpy as np
import pytest

from repro.core import arrivals, cost, hierarchy as h, projections as proj
from repro.core import throughput as tp


class TestHierarchy:
    def test_nameplate_capacities(self):
        assert h.design_4n3().ha_capacity_kw == 7500
        assert h.design_3p1().ha_capacity_kw == 7500
        assert h.design_10n8().ha_capacity_kw == 20000
        assert h.design_8p2().ha_capacity_kw == 20000

    def test_row_wiring_feed_counts(self):
        t = h.build_topology(h.design_4n3())
        assert (t.row_nfeeds[~t.row_is_hd] == 2).all()   # App. C.2 LD
        assert (t.row_nfeeds[t.row_is_hd] == 4).all()    # App. C.2 HD
        tb = h.build_topology(h.design_3p1())
        assert (tb.row_nfeeds == 1).all()                # block: 1 primary

    def test_balanced_combos_4n3(self):
        t = h.build_topology(h.design_4n3())
        ld = t.row_feeds[~t.row_is_hd][:, :2]
        combos, counts = np.unique(np.sort(ld, 1), axis=0, return_counts=True)
        assert len(combos) == 6            # C(4,2)
        assert (counts == counts[0]).all()  # balanced

    def test_block_reserve_lineups_inactive(self):
        t = h.build_topology(h.design_3p1())
        assert t.lineup_is_active.sum() == 3
        assert not np.isin(3, t.row_feeds)  # reserve feeds no row

    def test_fleet_tiling_global_indices(self):
        t = h.build_topology(h.design_4n3(), n_halls=3)
        assert t.row_cap.shape[0] == 3 * 30
        assert t.row_feeds.max() == 3 * 4 - 1
        assert (t.row_hall == np.repeat([0, 1, 2], 30)).all()


class TestCost:
    def test_static_costs_match_paper(self):
        c43 = cost.initial_dollars_per_mw(h.design_4n3())
        c31 = cost.initial_dollars_per_mw(h.design_3p1())
        assert abs(c43 / 1e6 - 10.0) < 0.25      # paper: $10M/MW
        assert abs(c31 / 1e6 - 10.3) < 0.15      # paper: $10.3M/MW
        assert 0.015 < c31 / c43 - 1 < 0.04      # ~3% static gap (§3.1)

    def test_effective_cost_grows_with_stranding(self):
        d = h.design_4n3()
        base = cost.initial_dollars_per_mw(d)
        eff = cost.effective_dollars_per_mw(d, n_halls=10,
                                            deployed_mw=10 * 7.5 * 0.8)
        assert eff > base
        assert cost.stranding_cost_per_mw(d, 10, 10 * 7.5 * 0.8) > 0


class TestProjections:
    @pytest.mark.parametrize("year", sorted(proj.TABLE5_OBERON))
    def test_table5_oberon(self, year):
        for i, s in enumerate(proj.SCENARIOS):
            assert proj.gpu_rack_kw(year, s) == proj.TABLE5_OBERON[year][i]

    def test_table4_generative(self):
        p = proj.pkg_perf(2030, "oberon")
        assert abs(p["flops_pf"] - 84.5) < 0.5
        assert abs(p["hbm_bw_tbps"] - 29.1) < 0.2
        p = proj.pkg_perf(2034, "kyber")
        assert abs(p["flops_pf"] - 482.7) < 2
        assert abs(p["hbm_gb"] - 3906) < 10

    def test_nongpu_endpoints(self):
        assert abs(proj.compute_rack_kw(2034, proj.HIGH) - 52) < 0.5
        assert abs(proj.storage_rack_kw(2034, proj.LOW) - 18) < 0.5
        assert abs(proj.compute_rack_kw(2025, proj.MED) - 20) < 1e-6


class TestThroughput:
    def test_fig2_spread_exceeds_20x(self):
        d = lambda: tp.Deployment(proj.KYBER, 2030, 1, "high")
        small = tp.tps_per_watt(tp.MODELS["MoE-0.6T"], d())
        big = tp.tps_per_watt(tp.MODELS["MoE-401T"], d())
        assert small / big > 20

    def test_pod_gain_monotone_in_model_size(self):
        gains = []
        for name in ("MoE-19T", "MoE-132T", "MoE-401T"):
            m = tp.MODELS[name]
            d1 = tp.Deployment(proj.KYBER, 2028, 1, "high")
            d5 = tp.Deployment(proj.KYBER, 2028, 5, "high")
            gains.append(tp.tps_per_watt(m, d5) / tp.tps_per_watt(m, d1) - 1)
        assert gains[0] <= gains[1] <= gains[2]
        assert gains[0] < 0.01 and gains[2] > 0.2

    def test_decode_is_memory_or_comm_bound(self):
        m = tp.MODELS["MoE-132T"]
        d = tp.Deployment(proj.KYBER, 2028, 1, "high")
        which, _ = tp.bottleneck(m, d, "dec")
        assert which in ("memory", "comm")

    def test_locality_model(self):
        m = tp.MODELS["MoE-401T"]
        d1 = tp.Deployment(proj.KYBER, 2028, 1, "high")
        d7 = tp.Deployment(proj.KYBER, 2028, 7, "high")
        assert tp.n_domains(m, d1) > 1
        assert tp.f_ib(m, d7) <= tp.f_ib(m, d1)
        assert tp.f_ib(m, d1) == 1 - 1 / tp.n_domains(m, d1)   # Eq. 13

    def test_weight_bytes(self):
        m = tp.MODELS["MoE-0.6T"]
        expect = m.L * (4 * m.w ** 2 + m.E * 2 * m.w * m.FF)
        assert m.w_total_bytes == expect
        assert m.w_active_bytes < m.w_total_bytes


class TestArrivals:
    def test_envelope_total_power(self):
        env = arrivals.EnvelopeSpec(demand_scale=0.02)
        t = arrivals.generate_fleet_trace(env, seed=0)
        total_gw = t.total_kw / 1e6
        assert abs(total_gw - 0.2) / 0.2 < 0.1   # within 10% of 200 MW

    def test_trace_fields(self):
        env = arrivals.EnvelopeSpec(demand_scale=0.01, pod_racks=3)
        t = arrivals.generate_fleet_trace(env, seed=1)
        assert (t.lifetime_m >= 12).all()
        assert (t.month[np.argsort(t.month, kind='stable')] == t.month).all()
        assert t.is_pod[t.is_gpu].all()
        assert (t.n_racks[t.is_gpu] == 3).all()
        assert set(np.unique(t.class_id)) <= {0, 1, 2}

    def test_sku_alphas_bounded(self):
        env = arrivals.EnvelopeSpec(demand_scale=0.01)
        t = arrivals.generate_fleet_trace(env, seed=2)
        for year in (2026, 2030):
            sel = t.is_gpu == False  # noqa: E712
            assert t.rack_kw[sel].max() <= proj.compute_rack_kw(2034) + 1

    def test_mixed_trace_power_share(self):
        t = arrivals.sample_mixed_trace(3000, gpu_power_share=0.6, seed=3)
        kw = t.rack_kw * t.n_racks
        gpu_share = kw[t.is_gpu].sum() / kw.sum()
        assert 0.45 < gpu_share < 0.75
