"""Property tests for the streaming quantile estimators (ISSUE 8).

Two layers of obligation:

* estimator-level — `quantiles.hist_masked_quantiles` must stay within
  its documented hard bound (one bin width) of ``np.percentile`` on ANY
  masked [0, 1] stream, and `quantiles.p2_stream_quantiles` must track
  ``np.percentile`` on random streams from several distribution
  families with a tolerance that shrinks as the stream grows (P²
  carries no hard bound, so the obligation is statistical, not
  adversarial).
* fleet-level — with the default ``exact_quantiles=True`` the lifecycle
  results must be bitwise what the PR 5 goldens pinned, and the
  streaming path must agree with the exact path within one histogram
  bin on every month while leaving all non-quantile outputs untouched.

The properties run twice: through hypothesis (shrinking, adversarial
search) when it is installed, and through an always-on seeded fallback
harness (fixed adversarial cases + RandomState case generator) so the
obligations are enforced even on images without the dev extras.
"""
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # dev extra; the seeded harness still runs
    HAVE_HYPOTHESIS = False

import jax

from repro.core import hierarchy as h, projections as proj
from repro.core import quantiles as qt
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet

QS = (50.0, 90.0)
HIST_PAD = 128     # fixed padded lengths → each estimator jits once
P2_PAD = 4096

_hist = jax.jit(lambda x, m: qt.hist_masked_quantiles(x, m, QS))
_p2 = jax.jit(lambda x, m: qt.p2_stream_quantiles(x, m, QS))


def _padded(vals, keep, n_pad):
    x = np.zeros(n_pad, np.float32)
    m = np.zeros(n_pad, bool)
    x[:len(vals)] = vals
    m[:len(vals)] = keep
    return x, m


def _check_hist(vals, keep):
    """|hist − np.percentile| ≤ (hi − lo)/n_bins on a masked stream."""
    got = np.asarray(_hist(*_padded(vals, keep, HIST_PAD)))
    ref = np.percentile(vals[keep].astype(np.float64), QS)
    np.testing.assert_allclose(got, ref,
                               atol=1.0 / qt.DEFAULT_BINS + 1e-6)


def _family_stream(family, n, seed):
    rng = np.random.RandomState(seed)
    return {
        "uniform": lambda: rng.uniform(0.0, 1.0, n),
        "normal": lambda: rng.normal(0.0, 1.0, n),
        "exponential": lambda: rng.exponential(1.0, n),
    }[family]().astype(np.float32)


def _check_p2(family, n, seed):
    """P² vs np.percentile on a masked random stream.  No hard bound
    exists for P², so the tolerance is a function of the stream length:
    max(0.02, 3/√n) · scale — loose for short streams, ~2% of the
    distribution scale asymptotically."""
    vals = _family_stream(family, n, seed)
    # mask out a deterministic ~1/8 of the stream so the masked-update
    # path (carry frozen on ok=False) is always exercised
    keep = (np.arange(n) * 2654435761 % 8) != 0
    got = np.asarray(_p2(*_padded(vals, keep, P2_PAD)))
    kept = vals[keep].astype(np.float64)
    ref = np.percentile(kept, QS)
    scale = max(1.0, np.std(kept))
    tol = max(0.02, 3.0 / np.sqrt(keep.sum())) * scale
    np.testing.assert_allclose(got, ref, atol=tol)


# ---------------------------------------------------------------------------
# histogram estimator: hard error bound on arbitrary masked [0, 1] data
# ---------------------------------------------------------------------------

# fixed adversarial streams a bucketing estimator must survive: point
# masses, the two-point gap, bin-edge values, near-duplicates
_HIST_CASES = [
    np.array([0.5], np.float32),
    np.zeros(64, np.float32),
    np.ones(64, np.float32),
    np.array([0.0] * 9 + [1.0], np.float32),
    np.array([0.0, 1.0] * 32, np.float32),
    (np.arange(100, dtype=np.float32) / 99.0),
    np.repeat(np.float32(1.0 / qt.DEFAULT_BINS) *
              np.arange(4, dtype=np.float32), 16),
]


@pytest.mark.parametrize("i", range(len(_HIST_CASES)))
def test_hist_adversarial_cases(i):
    vals = _HIST_CASES[i]
    _check_hist(vals, np.ones(len(vals), bool))


@pytest.mark.parametrize("seed", range(25))
def test_hist_seeded_streams(seed):
    """Always-on property harness: random masked [0, 1] streams of
    random length, including clustered draws."""
    rng = np.random.RandomState(seed)
    n = rng.randint(1, HIST_PAD + 1)
    if seed % 3 == 0:      # clustered around few centers
        centers = rng.uniform(0.0, 1.0, rng.randint(1, 4))
        vals = np.clip(rng.choice(centers, n)
                       + rng.normal(0.0, 1e-3, n), 0.0, 1.0)
    else:
        vals = rng.uniform(0.0, 1.0, n)
    keep = rng.rand(n) < 0.8
    if not keep.any():
        keep[0] = True
    _check_hist(vals.astype(np.float32), keep)


if HAVE_HYPOTHESIS:
    @given(st.lists(
        st.tuples(st.floats(0.0, 1.0, allow_nan=False, width=32),
                  st.booleans()),
        min_size=1, max_size=HIST_PAD))
    @settings(max_examples=60, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_hist_hypothesis_streams(pairs):
        """Shrinking adversarial search over masked [0, 1] streams."""
        vals = np.array([v for v, _ in pairs], np.float32)
        keep = np.array([k for _, k in pairs], bool)
        if not keep.any():
            keep[0] = True
        _check_hist(vals, keep)


def test_hist_all_masked_is_nan():
    x = np.full(HIST_PAD, 0.5, np.float32)
    got = np.asarray(_hist(x, np.zeros(HIST_PAD, bool)))
    assert np.isnan(got).all()


# ---------------------------------------------------------------------------
# P² estimator: statistical tracking, tolerance shrinking with n
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["uniform", "normal", "exponential"])
@pytest.mark.parametrize("n,seed", [(8, 0), (37, 1), (200, 2),
                                    (1023, 3), (P2_PAD, 4)])
def test_p2_seeded_streams(family, n, seed):
    """Always-on property harness over distribution families × stream
    lengths (the tolerance tightens as n grows)."""
    _check_p2(family, n, seed)


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(["uniform", "normal", "exponential"]),
           st.integers(8, P2_PAD), st.integers(0, 2**31 - 1))
    @settings(max_examples=30, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def test_p2_hypothesis_streams(family, n, seed):
        _check_p2(family, n, seed)


@pytest.mark.parametrize("n,seed", [(1, 0), (2, 1), (3, 2), (4, 3),
                                    (4, 4), (1, 5)])
def test_p2_small_stream_is_exact(n, seed):
    """Streams shorter than five valid observations bypass the marker
    machinery entirely: the sorted bootstrap buffer yields the exact
    'linear' quantile."""
    vals = np.random.RandomState(seed).uniform(0.0, 1.0, n) \
        .astype(np.float32)
    got = np.asarray(_p2(*_padded(vals, np.ones(n, bool), P2_PAD)))
    ref = np.percentile(vals.astype(np.float64), QS)
    np.testing.assert_allclose(got, ref, atol=1e-5)


def test_p2_all_masked_is_nan():
    x = np.full(P2_PAD, 0.5, np.float32)
    got = np.asarray(_p2(x, np.zeros(P2_PAD, bool)))
    assert np.isnan(got).all()


# ---------------------------------------------------------------------------
# fleet integration: exact default pins the goldens, streaming tracks it
# ---------------------------------------------------------------------------

GOLDEN_ENV = EnvelopeSpec(demand_scale=0.01, gpu_scenario=proj.HIGH)


@pytest.fixture(scope="module")
def golden_runs():
    cfg = FleetConfig(h.get_design("3+1"), GOLDEN_ENV, seed=3)
    return (run_fleet(cfg),
            run_fleet(cfg, exact_quantiles=True),
            run_fleet(cfg, exact_quantiles=False))


def test_exact_mode_is_the_default_and_pins_goldens(golden_runs):
    """`exact_quantiles=True` must be bitwise the default path — the PR 5
    golden tail quantiles included."""
    default, exact, _ = golden_runs
    np.testing.assert_array_equal(default.p50_stranding,
                                  exact.p50_stranding)
    np.testing.assert_array_equal(default.p90_stranding,
                                  exact.p90_stranding)
    np.testing.assert_array_equal(default.halls_active, exact.halls_active)
    assert exact.n_halls_built == 14
    np.testing.assert_allclose(exact.final_deployed_mw, 77.8758, atol=0.01)
    np.testing.assert_allclose(exact.p50_stranding[-1], 0.2407, atol=2e-3)
    np.testing.assert_allclose(exact.p90_stranding[-1], 0.3062, atol=2e-3)


def test_streaming_within_one_bin_of_exact(golden_runs):
    """Streaming histogram p50/p90 within one bin width of the exact
    post-hoc reduction on every month (NaN months — no active halls —
    must coincide)."""
    _, exact, stream = golden_runs
    tol = 1.0 / qt.DEFAULT_BINS + 1e-6
    for attr in ("p50_stranding", "p90_stranding"):
        e, s = getattr(exact, attr), getattr(stream, attr)
        np.testing.assert_array_equal(np.isnan(e), np.isnan(s),
                                      err_msg=attr)
        ok = ~np.isnan(e)
        np.testing.assert_allclose(s[ok], e[ok], atol=tol, err_msg=attr)


def test_streaming_leaves_non_quantile_outputs_bitwise(golden_runs):
    """The streaming path only changes what the scan emits for the two
    quantile channels; every other output is the same program."""
    _, exact, stream = golden_runs
    assert exact.n_halls_built == stream.n_halls_built
    np.testing.assert_array_equal(exact.halls_active, stream.halls_active)
    np.testing.assert_array_equal(exact.deployed_mw, stream.deployed_mw)
    np.testing.assert_array_equal(exact.final_hall_stranding,
                                  stream.final_hall_stranding)
