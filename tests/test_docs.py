"""Docs front-door checks: the README/architecture guide exist, every
relative markdown link resolves, and the commands the quickstart quotes
reference files that are really there."""
import pathlib
import re
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]


def test_front_door_exists():
    for rel in ("README.md", "docs/architecture.md", "docs/scenarios.md",
                "benchmarks/README.md", "ROADMAP.md"):
        assert (REPO / rel).is_file(), f"missing {rel}"


def test_markdown_links_resolve():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    errors = check_links.check(REPO)
    assert not errors, "broken markdown links:\n" + "\n".join(errors)


def test_link_checker_cli_passes():
    """CI invokes the checker as a script; keep that path green too."""
    r = subprocess.run([sys.executable, "tools/check_links.py"], cwd=REPO,
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr


def test_quickstart_commands_reference_real_files():
    """Paths quoted in README code fences must exist (commands 'run as
    written' is enforced by CI actually running them; this guards the
    file references)."""
    readme = (REPO / "README.md").read_text()
    for rel in re.findall(r"(?:examples|benchmarks|tools)/[\w./]+\.py",
                          readme):
        assert (REPO / rel).is_file(), f"README references missing {rel}"
    assert "PYTHONPATH=src python -m pytest -x -q" in readme, \
        "README must quote the tier-1 verify command"


def test_scenario_catalog_commands_run_as_written():
    """Every command docs/scenarios.md quotes must reference real files,
    real generator families, and a registered benchmark (CI runs the
    commands themselves in the scenario-study smoke step)."""
    doc = (REPO / "docs" / "scenarios.md").read_text()
    for rel in re.findall(r"(?:examples|benchmarks|tools)/[\w./]+\.py", doc):
        assert (REPO / rel).is_file(), f"scenarios.md references missing {rel}"

    from repro.core import scenarios
    families = re.findall(r"--family (\w+)", doc)
    assert set(families) == set(scenarios.FAMILIES), \
        "catalog must document a run command per family"
    # the generators the catalog names must exist with those knobs
    for fn, knob in (("demand_shocks", "multipliers"),
                     ("correlated_cohorts", "windows_m"),
                     ("mix_sweeps", "gpu_share_end"),
                     ("refresh_waves", "cycles_m")):
        assert f"scenarios.{fn}" in doc
        gen = getattr(scenarios, fn)
        assert knob in gen.__kwdefaults__, (fn, knob)

    assert "--only scenario_sweep" in doc
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks import run as bench_run
    finally:
        sys.path.pop(0)
    assert "scenario_sweep" in bench_run.REGISTRY


def test_architecture_module_references_exist():
    """Every `src/repro/...` path docs/architecture.md names must exist."""
    doc = (REPO / "docs" / "architecture.md").read_text()
    for rel in set(re.findall(r"(?:src/repro|sharding|core)/[\w/]+\.py",
                              doc)):
        if not rel.startswith("src/"):
            rel = "src/repro/" + rel
        assert (REPO / rel).is_file(), \
            f"architecture.md references missing {rel}"
