"""Resilient sweep execution (checkpoint/resume, fault isolation,
validation — `repro.core.resilience`).

The contract under test is *bitwise*: because the batch is prepared once
and every chunk / bisection sub-range is a slice of the same prepared
batch evaluated by the same jitted engine, a resumed (or retried, or
bisected-around-a-poisoned-config) run must reproduce the uninterrupted
`sweep()` / `mc_sweep()` arrays exactly — not within tolerance.  The
CI `resilience_resume` benchmark leg asserts the same property on a
512-configuration grid; here a small grid covers every code path.
"""
import os
import sys

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2").strip()

import dataclasses  # noqa: E402
import json  # noqa: E402

import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.checkpoint.checkpointer import LEAVES  # noqa: E402
from repro.core import hierarchy as h, placement as pl  # noqa: E402
from repro.core import projections as proj  # noqa: E402
from repro.core.arrivals import EnvelopeSpec  # noqa: E402
from repro.core.hierarchy import SweepValidationError  # noqa: E402
from repro.core.mc_sweep import MCAxes, mc_sweep  # noqa: E402
from repro.core.resilience import (RUN_MANIFEST, FaultPlan,  # noqa: E402
                                   InjectedCrash, ResumeMismatchError,
                                   resilient_mc_sweep, resilient_sweep)
from repro.core.sweep import SweepAxes, sweep  # noqa: E402
from repro.runtime.fault import Backoff  # noqa: E402

SCALE = 0.004
# zero base delay: retry schedules stay instant but keep their counts
NO_WAIT = Backoff(base_s=0.0, max_retries=2)

SWEEP_FIELDS = ("halls_active", "deployed_mw", "p50_stranding",
                "p90_stranding", "final_hall_stranding",
                "final_lineup_stranding", "n_halls_built",
                "final_deployed_mw", "placed_fraction", "initial_dpm",
                "effective_dpm", "total_capex", "provisioned_mw",
                "delivered_tps", "tps_per_provisioned_w",
                "dollars_per_tps")
MC_FIELDS = ("lineup_stranding", "hall_stranding", "deployed_kw",
             "saturated", "placed_a", "placed_b", "ha_capacity_kw",
             "provisioned_mw", "delivered_tps", "tps_per_provisioned_w",
             "dollars_per_tps")


def _env(sc=proj.MED):
    return EnvelopeSpec(demand_scale=SCALE, gpu_scenario=sc,
                        end_year=2028)


def _assert_bitwise(res, ref, fields, rows=None):
    """`rows=None`: whole arrays bitwise-equal.  `rows=mask/index`:
    only those leading-axis rows (the not-quarantined comparison)."""
    for f in fields:
        a, b = np.asarray(getattr(res, f)), np.asarray(getattr(ref, f))
        if rows is not None:
            a, b = a[rows], b[rows]
        np.testing.assert_array_equal(a, b, err_msg=f)


@pytest.fixture(scope="module")
def axes8():
    """8 configurations (2 designs × 2 envelopes × 2 seeds), B=8 so a
    chunk_size of 3 exercises a ragged last chunk."""
    return SweepAxes.product(
        designs=[h.get_design("4N/3"), h.get_design("3+1")],
        envs=[_env(proj.MED), _env(proj.HIGH)], seeds=(0, 1))


@pytest.fixture(scope="module")
def base8(axes8):
    """The uninterrupted one-shot reference result."""
    return sweep(axes8)


@pytest.fixture(scope="module")
def mc_axes3():
    return MCAxes.zip(
        designs=[h.get_design(n) for n in ("4N/3", "3+1", "10N/8")],
        seeds=[11, 12, 13])


MC_KW = dict(n_trials=2, n_events=80, year=2030, scenario=proj.HIGH)


@pytest.fixture(scope="module")
def mc_base3(mc_axes3):
    return mc_sweep(mc_axes3, **MC_KW)


# ---------------------------------------------------------------------------
# resilient_sweep ≡ sweep (no faults)
# ---------------------------------------------------------------------------

class TestResilientEqualsSweep:
    @pytest.mark.parametrize("chunk", [1, 3, 8])
    def test_chunked_bitwise_equals_one_shot(self, axes8, base8, chunk):
        res = resilient_sweep(axes8, chunk_size=chunk)
        _assert_bitwise(res, base8, SWEEP_FIELDS)
        r = res.report
        assert r.n_configs == 8 and r.chunk_size == chunk
        assert r.n_chunks == -(-8 // chunk) == r.chunks_computed
        assert r.chunks_resumed == 0 and not r.quarantined

    def test_default_chunk_is_whole_batch(self, axes8, base8):
        res = resilient_sweep(axes8)
        assert res.report.n_chunks == 1
        _assert_bitwise(res, base8, SWEEP_FIELDS)


# ---------------------------------------------------------------------------
# kill-and-resume
# ---------------------------------------------------------------------------

class TestKillAndResume:
    @pytest.mark.parametrize("crash_after", [0, 1, 2])
    def test_resume_bitwise_after_every_chunk_boundary(
            self, axes8, base8, tmp_path, crash_after):
        """Kill right after each chunk commits (3 chunks of ≤3); the
        resumed result must be bitwise-identical to the uninterrupted
        run, recomputing only the chunks that never committed."""
        ck = str(tmp_path)
        with pytest.raises(InjectedCrash):
            resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck,
                            fault_plan=FaultPlan(crash_after=crash_after))
        res = resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        _assert_bitwise(res, base8, SWEEP_FIELDS)
        assert res.report.chunks_resumed == crash_after + 1
        assert res.report.chunks_computed == 3 - (crash_after + 1)

    def test_completed_run_resumes_fully_then_rejects_other_grid(
            self, axes8, base8, tmp_path):
        ck = str(tmp_path)
        resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        res = resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        assert res.report.chunks_resumed == 3
        assert res.report.chunks_computed == 0
        _assert_bitwise(res, base8, SWEEP_FIELDS)
        # a different chunk grid (or axes) is a different run: refuse to
        # clobber the directory instead of silently mixing slabs
        with pytest.raises(ResumeMismatchError):
            resilient_sweep(axes8, chunk_size=4, checkpoint_dir=ck)

    def test_torn_manifest_discards_chunks_and_restarts(
            self, axes8, base8, tmp_path):
        """A manifest killed mid-write is unprovable: the chunks are
        discarded and the run starts fresh — still bitwise."""
        ck = str(tmp_path)
        with pytest.raises(InjectedCrash):
            resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck,
                            fault_plan=FaultPlan(crash_after=1))
        raw = (tmp_path / RUN_MANIFEST).read_text()
        (tmp_path / RUN_MANIFEST).write_text(raw[:len(raw) // 2])
        res = resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        assert res.report.chunks_resumed == 0
        assert res.report.chunks_computed == 3
        _assert_bitwise(res, base8, SWEEP_FIELDS)
        # the fresh run rewrote a valid manifest
        m = json.loads((tmp_path / RUN_MANIFEST).read_text())
        assert m["fingerprint"] == res.report.fingerprint

    def test_torn_chunk_payload_recomputed(self, axes8, base8, tmp_path):
        """A committed chunk whose payload bytes were torn fails its
        checksum on resume and is recomputed; intact chunks resume."""
        ck = str(tmp_path)
        with pytest.raises(InjectedCrash):
            resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck,
                            fault_plan=FaultPlan(crash_after=1))
        payload = tmp_path / "step_00000001" / LEAVES
        raw = bytearray(payload.read_bytes())
        raw[-1] ^= 0xFF
        payload.write_bytes(bytes(raw))
        res = resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        assert res.report.chunks_resumed == 1      # chunk 0 only
        assert res.report.chunks_computed == 2
        _assert_bitwise(res, base8, SWEEP_FIELDS)


# ---------------------------------------------------------------------------
# fault isolation / quarantine
# ---------------------------------------------------------------------------

class TestQuarantine:
    def test_poisoned_config_isolated_others_bitwise(self, axes8, base8):
        """One config that crashes every evaluation: bisection must
        quarantine exactly it; all other rows bitwise unchanged."""
        res = resilient_sweep(axes8, chunk_size=3,
                              fault_plan=FaultPlan(poison=(5,)),
                              backoff=NO_WAIT)
        r = res.report
        assert r.quarantined_indices() == (5,)
        q = r.quarantined[0]
        assert q.reason == "crash" and "poisoned" in q.error
        keep = [i for i in range(8) if i != 5]
        _assert_bitwise(res, base8, SWEEP_FIELDS, rows=keep)
        # the quarantined row carries sentinels in every representation
        assert np.isnan(res.final_deployed_mw[5])
        assert np.isnan(res.deployed_mw[5]).all()
        assert int(res.n_halls_built[5]) == -1
        assert np.isnan(res.total_capex[5])
        assert np.isnan(res.dollars_per_tps[5]).all()

    def test_nan_output_quarantined(self, axes8, base8):
        res = resilient_sweep(axes8, chunk_size=3,
                              fault_plan=FaultPlan(nan=(2,)),
                              backoff=NO_WAIT)
        assert res.report.quarantined_indices() == (2,)
        assert res.report.quarantined[0].reason == "nan-output"
        keep = [i for i in range(8) if i != 2]
        _assert_bitwise(res, base8, SWEEP_FIELDS, rows=keep)
        assert np.isnan(res.placed_fraction[2])

    def test_oom_halves_dispatch_without_losing_rows(self, axes8, base8):
        """Injected OOM on full-chunk dispatches forces one halving;
        every row still completes (no quarantine) and stays bitwise."""
        res = resilient_sweep(axes8, chunk_size=8,
                              fault_plan=FaultPlan(oom={0: 1}),
                              backoff=NO_WAIT)
        assert res.report.oom_halvings >= 1
        assert not res.report.quarantined
        _assert_bitwise(res, base8, SWEEP_FIELDS)

    def test_transient_failure_retried_to_success(self, axes8, base8):
        """A chunk whose first two attempts fail succeeds on the third:
        retries are counted, nothing is quarantined, result bitwise."""
        res = resilient_sweep(axes8, chunk_size=3,
                              fault_plan=FaultPlan(fail={1: 2}),
                              backoff=NO_WAIT)
        assert res.report.retries == 2
        assert not res.report.quarantined
        _assert_bitwise(res, base8, SWEEP_FIELDS)

    def test_quarantine_survives_kill_and_resume(self, axes8, base8,
                                                 tmp_path):
        """Quarantine metadata rides inside the committed chunk slabs:
        a resume re-registers it without re-running the poison."""
        ck = str(tmp_path)
        with pytest.raises(InjectedCrash):
            resilient_sweep(
                axes8, chunk_size=3, checkpoint_dir=ck,
                fault_plan=FaultPlan(poison=(5,), crash_after=1),
                backoff=NO_WAIT)
        res = resilient_sweep(axes8, chunk_size=3, checkpoint_dir=ck)
        r = res.report
        assert r.chunks_resumed == 2 and r.chunks_computed == 1
        assert r.quarantined_indices() == (5,)
        assert r.quarantined[0].reason == "crash"
        keep = [i for i in range(8) if i != 5]
        _assert_bitwise(res, base8, SWEEP_FIELDS, rows=keep)
        assert np.isnan(res.final_deployed_mw[5])


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------

class TestValidation:
    def test_zip_length_mismatch_names_offending_field(self):
        with pytest.raises(SweepValidationError) as e:
            SweepAxes.zip(designs=[h.get_design("4N/3")] * 3,
                          envs=[_env()] * 2)
        assert e.value.field == "envs"
        with pytest.raises(SweepValidationError) as e:
            MCAxes.zip(designs=[h.get_design("4N/3")] * 3,
                       seeds=[1, 2])
        assert e.value.field == "seeds"

    def test_zero_row_design_rejected(self):
        d = dataclasses.replace(h.get_design("4N/3"), ld_rows=0,
                                hd_rows=0)
        with pytest.raises(SweepValidationError, match="zero rows"):
            d.validate()
        with pytest.raises(SweepValidationError, match="zero rows"):
            h.build_topology(d, 1)

    def test_zero_feed_design_rejected(self):
        d = dataclasses.replace(h.get_design("4N/3"), ld_feeds=0)
        with pytest.raises(SweepValidationError) as e:
            h.build_topology(d, 1)
        assert e.value.field == "ld_feeds"
        assert "zero-feed" in str(e.value)

    def test_envelope_catalog(self):
        with pytest.raises(SweepValidationError,
                           match="non-monotone buildout horizon"):
            EnvelopeSpec(start_year=2030, end_year=2028).validate()
        with pytest.raises(SweepValidationError) as e:
            EnvelopeSpec(pod_racks=pl.MAX_POD_RACKS + 1).validate()
        assert e.value.field == "pod_racks"
        with pytest.raises(SweepValidationError) as e:
            EnvelopeSpec(demand_scale=0.0).validate()
        assert e.value.field == "demand_scale"

    def test_axes_catalog(self):
        with pytest.raises(SweepValidationError) as e:
            SweepAxes.zip(designs=[h.get_design("4N/3")],
                          envs=[_env()], policies=[99]).validate()
        assert e.value.field == "policies"
        with pytest.raises(SweepValidationError) as e:
            SweepAxes.zip(designs=[], envs=[]).validate()
        assert e.value.field == "designs"
        with pytest.raises(SweepValidationError) as e:
            MCAxes.zip(designs=[h.get_design("4N/3")],
                       sku_kw=[-1.0]).validate()
        assert e.value.field == "sku_kw"

    def test_sweep_validates_before_compile(self):
        """The engines call `axes.validate()` inside prepare — a bad
        grid dies with the precise error, not a trace-time failure."""
        bad = SweepAxes.zip(designs=[h.get_design("4N/3")],
                            envs=[_env()], policies=[99])
        with pytest.raises(SweepValidationError):
            sweep(bad)
        with pytest.raises(SweepValidationError):
            resilient_sweep(bad)

    def test_error_is_a_value_error(self):
        """Pre-existing callers catching ValueError keep working."""
        assert issubclass(SweepValidationError, ValueError)


# ---------------------------------------------------------------------------
# resilient_mc_sweep
# ---------------------------------------------------------------------------

class TestMCResilience:
    def test_chunked_bitwise_equals_one_shot(self, mc_axes3, mc_base3):
        res = resilient_mc_sweep(mc_axes3, chunk_size=2, **MC_KW)
        _assert_bitwise(res, mc_base3, MC_FIELDS)
        assert res.report.n_chunks == 2 and not res.report.quarantined

    def test_kill_and_resume_bitwise(self, mc_axes3, mc_base3, tmp_path):
        ck = str(tmp_path)
        with pytest.raises(InjectedCrash):
            resilient_mc_sweep(mc_axes3, chunk_size=2, checkpoint_dir=ck,
                               fault_plan=FaultPlan(crash_after=0),
                               **MC_KW)
        res = resilient_mc_sweep(mc_axes3, chunk_size=2,
                                 checkpoint_dir=ck, **MC_KW)
        assert res.report.chunks_resumed == 1
        assert res.report.chunks_computed == 1
        _assert_bitwise(res, mc_base3, MC_FIELDS)

    def test_poisoned_config_isolated(self, mc_axes3, mc_base3):
        res = resilient_mc_sweep(mc_axes3, chunk_size=2,
                                 fault_plan=FaultPlan(poison=(1,)),
                                 backoff=NO_WAIT, **MC_KW)
        assert res.report.quarantined_indices() == (1,)
        keep = [0, 2]
        _assert_bitwise(res, mc_base3, MC_FIELDS, rows=keep)
        assert np.isnan(res.deployed_kw[1]).all()
        assert np.isnan(res.ha_capacity_kw[1])
