"""Metric stack: throughput-model references (Eqs. 6–16), the batched
grid evaluator, the sweep/mc $/performance columns, the corrected
fleet-level TPS/W normalization, and the design frontier."""
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core import cost, hierarchy as h, mc_sweep as mcs, payoff
from repro.core import projections as proj, sweep as sw, throughput as tp
from repro.core.arrivals import EnvelopeSpec

TINY = tp.MoEModel("tiny", L=2, w=64, E=4, K=2, S=8)


class TestThroughputReferences:
    """Hand-computed Eq. 6–16 values for a small model/deployment pair."""

    def test_eq6_prefill_flops(self):
        # L·(4·K·w·FF + 4·w² + 2·w·s_p) with L=2, w=64, FF=256, K=2:
        # 4·2·64·256 = 131072;  4·64² = 16384;  2·64·8 = 1024 → ×2 = 296960
        assert float(tp.c_prefill(TINY, 8)) == 296960.0
        # Eq. 7 has the same form in the generation index t
        assert float(tp.c_decode(TINY, 8)) == 296960.0

    def test_eq8_eq9_bytes_per_token(self):
        # Eq. 8: W_total/(B·s_p) + 2·L·w·b_kv.  W_total = L(4w² + E·2·w·FF)
        w_total = 2 * (4 * 64 ** 2 + 4 * 2 * 64 * 256) * 1.0
        assert TINY.w_total_bytes == w_total == 294912.0
        assert tp.m_prefill(TINY, 8, batch=4) == w_total / 32 + 128.0
        # Eq. 9: W_active/B + 2·L·w·(t+1)·b_kv, W_active at K=2 experts
        w_active = 2 * (4 * 64 ** 2 + 2 * 2 * 64 * 256) * 1.0
        assert TINY.w_active_bytes == w_active == 163840.0
        assert float(tp.m_decode(TINY, 3, batch=4)) == w_active / 4 + 4 * 128.0

    def test_eq10_eq11_collective_bytes(self):
        # Eq. 10: L·2·(T−1)/T·w·b_act at TP degree 4 → 2·2·(3/4)·64·0.5
        assert tp.n_tp(TINY, 4) == 96.0
        # Eq. 11: 2·L·K·w·b_act
        assert tp.n_ep(TINY) == 256.0

    def test_eq12_eq13_locality(self):
        m = tp.MODELS["MoE-401T"]
        d = tp.Deployment(proj.KYBER, 2028, 1, "high")
        # Eq. 12: ceil(W_total / (α·domain_pkgs·HBM_pkg))
        usable = tp.ALPHA_HBM * d.domain_pkgs * d.hbm_pkg_bytes
        nd = int(np.ceil(m.w_total_bytes / usable))
        assert tp.n_domains(m, d) == nd > 1
        assert tp.f_ib(m, d) == 1.0 - 1.0 / nd     # Eq. 13

    def test_eq14_16_comm_and_incast_share(self):
        m = tp.MODELS["MoE-401T"]
        d = tp.Deployment(proj.KYBER, 2028, 1, "high")
        nd = tp.n_domains(m, d)
        f = 1.0 - 1.0 / nd
        # Eq. 14–16 assembled from the primitive terms: remote EP traffic
        # sees only the 1/n_d incast share of the scale-out fabric
        expect = (tp.n_tp(m, d.tp_degree) / d.b_nvl
                  + max((1 - f) * tp.n_ep(m) / d.b_nvl,
                        f * tp.n_ep(m) / (d.b_ib(m) / nd)))
        assert tp.t_comm(m, d) == pytest.approx(expect, rel=1e-12)
        # without the incast penalty the remote term is n_d× cheaper
        d_no = tp.Deployment(proj.KYBER, 2028, 1, "high",
                             incast_penalty=False)
        assert tp.t_comm(m, d_no) < tp.t_comm(m, d)

    def test_c_prefill_dtype_unified(self):
        # the old code forked on hasattr(s_p, "shape") and requested
        # float64 on the array branch (silently downcast without x64)
        arr = tp.c_prefill(TINY, np.array([8.0, 16.0]))
        scl = tp.c_prefill(TINY, 8.0)
        assert arr.dtype == scl.dtype == tp.DTYPE
        assert float(arr[0]) == float(scl)


class TestGridEvaluator:
    def test_grid_matches_scalar_loop(self):
        """One jitted [C, M] grid ≡ the per-pair Python loop (Table 2)."""
        deps = [tp.Deployment(proj.KYBER, 2028, n, "high")
                for n in (1, 3, 5, 7)]
        grid = np.asarray(tp.tps_request_grid(tp.MODEL_SUITE, deps))
        loop = np.array([[float(tp.tps_request(m, d))
                          for m in tp.MODEL_SUITE] for d in deps])
        np.testing.assert_allclose(grid, loop, rtol=1e-4)

    def test_per_watt_grid_matches_scalar(self):
        deps = [tp.Deployment(proj.KYBER, 2030, 1, "med"),
                tp.Deployment(proj.VERA_RUBIN, 2030, 1, "med")]
        grid = np.asarray(tp.tps_per_watt_grid(tp.MODEL_SUITE, deps))
        loop = np.array([[tp.tps_per_watt(m, d)
                          for m in tp.MODEL_SUITE] for d in deps])
        np.testing.assert_allclose(grid, loop, rtol=1e-4)

    def test_pair_statics_hoists_locality_ints(self):
        m = tp.MODELS["MoE-401T"]
        d = tp.Deployment(proj.KYBER, 2028, 1, "high")
        st = tp.pair_statics(m, d)
        assert st.f_flops == d.f_flops(m)       # includes n_units scaling
        assert st.t_comm == tp.t_comm(m, d)     # includes n_domains/incast
        assert st.power_w == d.power_w(m)


class TestCostSentinels:
    def test_effective_dpm_nan_not_inf_when_undeployed(self):
        d = h.get_design("4N/3")
        assert np.isnan(cost.effective_dollars_per_mw(d, 5, 0.0))
        assert np.isnan(cost.stranding_cost_per_mw(d, 5, 0.0))
        assert np.isfinite(cost.effective_dollars_per_mw(d, 5, 10.0))

    def test_dollars_per_tps_sentinel(self):
        assert np.isnan(cost.dollars_per_tps(1e9, 0.0))
        assert np.isnan(cost.dollars_per_tps(1e9, float("nan")))
        assert cost.dollars_per_tps(1e9, 1e6) == 1e3


class TestSweepMetricColumns:
    @pytest.fixture(scope="class")
    def res(self):
        axes = sw.SweepAxes.product(
            [h.get_design("4N/3"), h.get_design("3+1")],
            [EnvelopeSpec(demand_scale=0.01, gpu_scenario=proj.HIGH)],
            seeds=(0,))
        return axes, sw.sweep(axes)

    def test_columns_present_and_consistent(self, res):
        axes, r = res
        B, M = len(axes), len(tp.MODEL_SUITE)
        assert r.model_names == [m.name for m in tp.MODEL_SUITE]
        assert r.delivered_tps.shape == (B, M)
        assert r.tps_per_provisioned_w.shape == (B, M)
        assert r.dollars_per_tps.shape == (B, M)
        # delivered = serving TPS/W × deployed GPU watts, per envelope
        env = axes.envs[0]
        dep = tp.serving_deployment(env.end_year, env.gpu_scenario,
                                    env.pod_racks)
        share = sw.gpu_power_share(env)
        for i in (0, 1):
            expect = (tp.tps_per_watt(tp.MODEL_SUITE[0], dep)
                      * r.final_deployed_mw[i] * 1e6 * share)
            assert r.delivered_tps[i, 0] == pytest.approx(expect, rel=1e-4)
        # provisioned = halls built × HA nameplate
        np.testing.assert_allclose(
            r.provisioned_mw,
            [int(n) * d.ha_capacity_kw / 1e3
             for d, n in zip(axes.designs, r.n_halls_built)])
        np.testing.assert_allclose(
            r.tps_per_provisioned_w,
            r.delivered_tps / (r.provisioned_mw[:, None] * 1e6))
        np.testing.assert_allclose(
            r.dollars_per_tps,
            r.total_capex[:, None] / r.delivered_tps)

    def test_stranding_outputs_identical_without_metric_stage(self, res):
        """`models=()` skips the stage; every simulation output must be
        bit-identical (the metric stage is strictly post-`_finalize`)."""
        axes, r = res
        r0 = sw.sweep(axes, models=())
        assert r0.delivered_tps.shape == (len(axes), 0)
        for f in ("p50_stranding", "p90_stranding", "deployed_mw",
                  "final_lineup_stranding", "n_halls_built",
                  "final_deployed_mw", "placed_fraction"):
            np.testing.assert_array_equal(getattr(r0, f), getattr(r, f))

    def test_models_accepted_by_name(self, res):
        """`models=` takes Table 2 names as well as `MoEModel` objects."""
        axes, r = res
        rn = sw.sweep(axes, models=("MoE-132T", "MoE-401T"))
        assert rn.model_names == ["MoE-132T", "MoE-401T"]
        cols = [r.model_names.index(n) for n in rn.model_names]
        np.testing.assert_array_equal(rn.delivered_tps,
                                      r.delivered_tps[:, cols])
        np.testing.assert_array_equal(rn.dollars_per_tps,
                                      r.dollars_per_tps[:, cols])

    def test_mc_sweep_metric_columns(self):
        r = mcs.mc_sweep(mcs.MCAxes.zip([h.get_design("4N/3")]),
                         n_trials=4, n_events=120, year=2028,
                         scenario=proj.HIGH, gpu_power_share=0.6)
        B, T, M = 1, 4, len(tp.MODEL_SUITE)
        assert r.delivered_tps.shape == (B, T, M)
        dep = tp.serving_deployment(2028, proj.HIGH, 1)
        expect = (tp.tps_per_watt(tp.MODEL_SUITE[2], dep)
                  * r.deployed_kw[0] * 1e3 * 0.6)
        np.testing.assert_allclose(r.delivered_tps[0, :, 2], expect,
                                   rtol=1e-4)
        assert np.isfinite(r.dollars_per_tps).all()
        np.testing.assert_allclose(
            r.tps_per_provisioned_w[0],
            r.delivered_tps[0] / (r.provisioned_mw[0] * 1e6))


class TestFleetTpwRegression:
    """The old fleet_tpw normalized by deployed MW — which algebraically
    cancels, reducing the metric to tw·gpu_share regardless of how much
    built capacity is stranded."""

    ENV = EnvelopeSpec(demand_scale=0.05, gpu_scenario=proj.HIGH,
                       pod_scale_arch=True)

    def _study(self, deployed_mw, n_halls):
        cache = {1: SimpleNamespace(effective_dpm=1e7,
                                    final_deployed_mw=deployed_mw,
                                    n_halls_built=n_halls)}
        (pt,) = payoff.pod_payoff_study(
            h.get_design("4N/3"), [tp.MODELS["MoE-132T"]], pod_sizes=(1,),
            env=self.ENV, fleet_cache=cache)
        return pt

    def test_higher_stranding_lowers_fleet_tpw(self):
        # equal serving gain (same model, same pod size), 10 halls built:
        # 75 MW deployed = zero stranding; 60 MW = 20% stranded
        full = self._study(deployed_mw=75.0, n_halls=10)
        strand = self._study(deployed_mw=60.0, n_halls=10)
        assert full.fleet_tps_per_watt > strand.fleet_tps_per_watt > 0
        assert strand.fleet_tps_per_watt == pytest.approx(
            full.fleet_tps_per_watt * 60.0 / 75.0, rel=1e-9)

    def test_cancellation_is_gone(self):
        # the old formula equalled tw·gpu_share for ANY deployed MW;
        # the stranded fleet must now fall below that ceiling
        strand = self._study(deployed_mw=60.0, n_halls=10)
        share = sw.gpu_power_share(self.ENV)
        assert strand.fleet_tps_per_watt < strand.tps_per_watt * share
        # and an unstranded fleet still attains it exactly
        full = self._study(deployed_mw=75.0, n_halls=10)
        assert full.fleet_tps_per_watt == pytest.approx(
            full.tps_per_watt * share, rel=1e-9)

    def test_nan_when_nothing_built(self):
        pt = self._study(deployed_mw=0.0, n_halls=0)
        assert np.isnan(pt.fleet_tps_per_watt)


class TestDesignFrontier:
    def test_pareto_mask(self):
        perf = np.array([1.0, 2.0, 3.0, 2.0, np.nan])
        capex = np.array([1.0, 1.0, 2.0, np.nan, 1.0])
        dom = payoff.pareto_dominated(perf, capex)
        # 0 beaten by 1; 1 and 2 on the frontier; non-finite always out
        assert dom.tolist() == [True, False, False, True, True]

    def test_rel_delta_nan_safety(self):
        assert payoff._rel_delta(2.0, 1.0) == 1.0
        assert payoff._rel_delta(5.0, 5.0) == 0.0
        assert np.isnan(payoff._rel_delta(2.0, 0.0))
        assert np.isnan(payoff._rel_delta(float("nan"), 1.0))
        assert np.isnan(payoff._rel_delta(2.0, float("inf")))

    def test_design_frontier_grid(self):
        env = EnvelopeSpec(demand_scale=0.01, gpu_scenario=proj.HIGH)
        pts = payoff.design_frontier(base_env=env, seeds=(0,),
                                     models=[tp.MODELS["MoE-132T"]])
        assert len(pts) == 8                      # 4 designs × {1,5} pods
        assert {p.tag for p in pts} == {"pod:p1", "pod:p5"}
        front = [p for p in pts if not p.dominated]
        assert front, "frontier must be non-empty"
        # no frontier point may be beaten on both axes by any other point
        for f in front:
            for q in pts:
                better = (q.delivered_tps >= f.delivered_tps
                          and q.total_capex <= f.total_capex
                          and (q.delivered_tps > f.delivered_tps
                               or q.total_capex < f.total_capex))
                assert not (np.isfinite(q.delivered_tps) and better)
