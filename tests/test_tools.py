"""Unit tests for the CI gate tools: exit-code contract and
offending-row/link reporting for tools/check_speedups.py and
tools/check_links.py.

Contract (both tools): 0 = clean, 1 = the gate itself failed,
2 = the input is missing/unreadable.  CI legs rely on the distinction
to tell "a benchmark regressed" apart from "the dump never got
written".
"""
import json
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO / "tools"))

import check_links  # noqa: E402
import check_speedups  # noqa: E402


def run_speedups(*args):
    return subprocess.run(
        [sys.executable, "tools/check_speedups.py", *args],
        cwd=REPO, capture_output=True, text=True)


def run_links(*args):
    return subprocess.run(
        [sys.executable, "tools/check_links.py", *args],
        cwd=REPO, capture_output=True, text=True)


def dump(tmp_path, rows, name="bench.json"):
    p = tmp_path / name
    p.write_text(json.dumps(rows))
    return str(p)


# ----------------------------------------------------------- check_speedups

def test_speedups_pass(tmp_path):
    p = dump(tmp_path, {"sweep.speedup": {"derived": "batched=2.10x"}})
    proc = run_speedups(p)
    assert proc.returncode == check_speedups.EXIT_OK
    assert "2.10x" in proc.stdout


def test_speedups_gate_failure_prints_offending_row(tmp_path):
    p = dump(tmp_path, {
        "sweep.speedup": {"derived": "batched=2.10x"},
        "mc.speedup": {"derived": "batched=0.40x"},
    })
    proc = run_speedups(p)
    assert proc.returncode == check_speedups.EXIT_GATE_FAILED
    assert "mc.speedup" in proc.stderr
    assert "0.40x" in proc.stderr and "batched=0.40x" in proc.stderr


def test_speedups_missing_file_is_exit_2(tmp_path):
    proc = run_speedups(str(tmp_path / "nope.json"))
    assert proc.returncode == check_speedups.EXIT_FILE_ERROR
    assert "cannot read" in proc.stderr


def test_speedups_invalid_json_is_exit_2(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text("{not json")
    proc = run_speedups(str(p))
    assert proc.returncode == check_speedups.EXIT_FILE_ERROR
    assert "not valid JSON" in proc.stderr


def test_speedups_empty_dump_is_gate_failure(tmp_path):
    p = dump(tmp_path, {"latency.p50": {"derived": "ms=3.2"}})
    proc = run_speedups(p)
    assert proc.returncode == check_speedups.EXIT_GATE_FAILED
    assert "no speedup ratios found" in proc.stderr


def test_speedups_per_row_floor_and_skip(tmp_path):
    p = dump(tmp_path, {
        "resilience.overhead_speedup": {"derived": "ckpt=0.95x;min=0.9"},
        "pod_sweep.speedup": {"derived": "skipped=1-device host"},
        "sweep.speedup": {"derived": "batched=1.50x"},
    })
    proc = run_speedups(p)
    assert proc.returncode == check_speedups.EXIT_OK, proc.stderr


def test_speedups_malformed_row_names_the_row(tmp_path):
    p = dump(tmp_path, {"mc.speedup": {"derived": "no ratio here"}})
    proc = run_speedups(p)
    assert proc.returncode == check_speedups.EXIT_GATE_FAILED
    assert "mc.speedup" in proc.stderr and "no ratio here" in proc.stderr


# -------------------------------------------------------------- check_links

def test_links_clean_tree(tmp_path):
    (tmp_path / "a.md").write_text("[ok](b.md)\n")
    (tmp_path / "b.md").write_text("see [a](a.md#top) and [web](https://x)\n")
    proc = run_links(str(tmp_path))
    assert proc.returncode == check_links.EXIT_OK
    assert "0 broken link(s)" in proc.stdout


def test_links_broken_link_printed(tmp_path):
    (tmp_path / "a.md").write_text("[gone](missing.md)\n")
    proc = run_links(str(tmp_path))
    assert proc.returncode == check_links.EXIT_BROKEN
    assert "a.md: broken link -> missing.md" in proc.stdout


def test_links_missing_root_is_exit_2(tmp_path):
    proc = run_links(str(tmp_path / "no_such_root"))
    assert proc.returncode == check_links.EXIT_BAD_ROOT
    assert "not a directory" in proc.stderr


def test_links_check_api_unchanged(tmp_path):
    """tests/test_docs.py imports check(root) -> list[str]; keep it."""
    (tmp_path / "a.md").write_text("[gone](missing.md)\n")
    errors = check_links.check(tmp_path)
    assert errors == ["a.md: broken link -> missing.md"]
