"""Fleet lifecycle simulator behaviour (paper §4.4/§6)."""
import numpy as np
import pytest

from repro.core import hierarchy as h, projections as proj
from repro.core.arrivals import EnvelopeSpec
from repro.core.fleet import FleetConfig, run_fleet

ENV = EnvelopeSpec(demand_scale=0.01, gpu_scenario=proj.HIGH)


@pytest.fixture(scope="module")
def results():
    out = {}
    for name in ("4N/3", "3+1"):
        out[name] = run_fleet(FleetConfig(h.get_design(name), ENV, seed=3))
    return out


def test_all_arrivals_placed(results):
    for r in results.values():
        assert r.placed_fraction == 1.0


def test_halls_grow_monotonically(results):
    for r in results.values():
        assert (np.diff(r.halls_active) >= 0).all()
        assert r.n_halls_built >= 2


def test_deployed_below_capacity(results):
    for name, r in results.items():
        cap = r.n_halls_built * h.get_design(name).ha_capacity_kw / 1e3
        assert 0 < r.final_deployed_mw <= cap


def test_stranding_bounded(results):
    for r in results.values():
        assert (r.p90_stranding >= 0).all() and (r.p90_stranding <= 1).all()
        assert (r.final_hall_stranding >= -1e-3).all()


def test_block_strands_more_at_high_tdp(results):
    """The paper's headline (§3.1/Fig. 13): under High TDP, 3+1 strands
    more and needs more halls than 4N/3 for the same demand."""
    r43, r31 = results["4N/3"], results["3+1"]
    assert r31.n_halls_built >= r43.n_halls_built
    assert r31.effective_dpm > r43.effective_dpm


def test_effective_exceeds_initial(results):
    for r in results.values():
        assert r.effective_dpm > r.initial_dpm


def test_harvest_reduces_halls():
    rh = run_fleet(FleetConfig(h.get_design("3+1"), ENV, harvest=True,
                               seed=5))
    rn = run_fleet(FleetConfig(h.get_design("3+1"), ENV, harvest=False,
                               seed=5))
    assert rh.n_halls_built <= rn.n_halls_built


def test_scale_stability():
    """Stranding fractions are demand-scale stable (DESIGN.md §4) —
    the reduced-scale benchmarks represent the 10 GW study."""
    p90 = []
    for scale in (0.01, 0.02):
        env = EnvelopeSpec(demand_scale=scale, gpu_scenario=proj.HIGH)
        r = run_fleet(FleetConfig(h.get_design("3+1"), env, seed=7))
        p90.append(r.p90_stranding[-1])
    assert abs(p90[0] - p90[1]) < 0.12


def test_masked_percentiles_all_false_mask_is_nan():
    """Regression (ISSUE 8): an all-False mask used to leak the +inf
    sort padding into the quantile; it must yield the NaN sentinel —
    matching the streaming estimators — while any non-empty mask stays
    exact np.percentile('linear')."""
    from repro.core.fleet import _masked_percentiles
    import jax.numpy as jnp

    x = jnp.asarray(np.linspace(0.0, 1.0, 7), jnp.float32)
    empty = _masked_percentiles(x, jnp.zeros(7, bool), (50.0, 90.0))
    assert all(np.isnan(np.asarray(v)) for v in empty)
    mask = np.array([1, 0, 1, 1, 0, 1, 1], bool)
    got = _masked_percentiles(x, jnp.asarray(mask), (50.0, 90.0))
    ref = np.percentile(np.asarray(x)[mask].astype(np.float64), (50, 90))
    np.testing.assert_allclose(np.asarray(got), ref, rtol=1e-6)
