"""GPipe pipeline-parallel equivalence tests (4-stage host mesh)."""
import os
import sys

if "jax" not in sys.modules:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.train.pipeline import pipeline, split_stages  # noqa: E402

needs_devices = pytest.mark.skipif(jax.device_count() < 4,
                                   reason="needs 4 host devices")


def _mesh():
    return jax.make_mesh((4,), ("stage",), devices=jax.devices()[:4])


def _mlp_stack(key, L=8, d=16):
    w = jax.random.normal(key, (L, d, d)) * 0.3
    b = jnp.zeros((L, d))
    return {"w": w, "b": b}


def _apply_layers(params, x):
    def body(x, p):
        return jnp.tanh(x @ p["w"] + p["b"]), None
    x, _ = jax.lax.scan(body, x, params)
    return x


@needs_devices
def test_pipeline_matches_sequential():
    key = jax.random.PRNGKey(0)
    params = _mlp_stack(key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (8, 16))
    ref = _apply_layers(params, x)

    mesh = _mesh()
    staged = split_stages(params, 4)     # [4, 2, d, d]
    pipe = pipeline(lambda p, xm: _apply_layers(p, xm), mesh,
                    n_microbatches=4)
    with mesh:
        out = jax.jit(pipe)(staged, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@needs_devices
def test_pipeline_grads_match():
    key = jax.random.PRNGKey(2)
    params = _mlp_stack(key, L=4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16))

    def loss_seq(p):
        return jnp.sum(_apply_layers(p, x) ** 2)

    mesh = _mesh()
    pipe = pipeline(lambda p, xm: _apply_layers(p, xm), mesh,
                    n_microbatches=2)

    def loss_pipe(staged):
        with mesh:
            return jnp.sum(pipe(staged, x) ** 2)

    g_ref = jax.grad(loss_seq)(params)
    g_pipe = jax.grad(loss_pipe)(split_stages(params, 4))
    for k in ("w", "b"):
        np.testing.assert_allclose(
            np.asarray(g_pipe[k]).reshape(np.asarray(g_ref[k]).shape),
            np.asarray(g_ref[k]), atol=1e-4)
