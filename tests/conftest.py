import gc
import os
import sys

import pytest

# Tests run on the single real CPU device (the 512-device override lives
# ONLY in repro.launch.dryrun).  test_sharding.py / test_pipeline.py force
# an 8-device host platform when they are the first jax importer (their
# own module-level env guard); under the full suite they skip if the
# device count is insufficient.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """The full suite compiles hundreds of executables; XLA:CPU's JIT
    memory is only reclaimed when the compilation cache is dropped.
    Without this, late modules die with 'LLVM compilation error: Cannot
    allocate memory' on this 35 GB container."""
    yield
    import jax
    jax.clear_caches()
    gc.collect()
