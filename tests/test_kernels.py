"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs pure-jnp
oracles (interpret mode on CPU; same kernels target TPU VMEM tiling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,Hk,hd,causal,dt", [
        (2, 128, 4, 2, 32, True, jnp.float32),
        (1, 96, 2, 2, 16, False, jnp.float32),
        (2, 64, 4, 1, 64, True, jnp.bfloat16),
        (1, 80, 8, 4, 32, True, jnp.float32),   # non-divisible seq (pad)
    ])
    def test_vs_oracle(self, B, S, H, Hk, hd, causal, dt):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import reference_attention
        q = jax.random.normal(KEY, (B, S, H, hd), dt)
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hk, hd), dt)
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hk, hd), dt)
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
        ref = jnp.swapaxes(reference_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
        tol = 0.05 if dt == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,nh,hd,st,chunk", [
        (2, 64, 4, 16, 8, 16),
        (1, 100, 8, 8, 16, 32),    # pad path
        (2, 128, 16, 32, 16, 64),
    ])
    def test_vs_naive_recurrence(self, B, S, nh, hd, st, chunk):
        from repro.kernels.ssd_scan.ops import ssd_scan
        from repro.kernels.ssd_scan.ref import reference_ssd
        ks = jax.random.split(KEY, 4)
        xdt = 0.5 * jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32)
        log_a = -0.5 * jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        b = 0.5 * jax.random.normal(ks[2], (B, S, st))
        c = 0.5 * jax.random.normal(ks[3], (B, S, st))
        out = ssd_scan(xdt, log_a, b, c, chunk=chunk, head_block=4,
                       interpret=True)
        ref = reference_ssd(xdt, log_a, b, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3)

    def test_model_chunked_matches_oracle(self):
        from repro.kernels.ssd_scan.ref import reference_ssd
        from repro.models.ssm import _ssd_chunked
        ks = jax.random.split(KEY, 4)
        xdt = 0.3 * jax.random.normal(ks[0], (2, 96, 4, 8), jnp.float32)
        log_a = -0.4 * jax.nn.softplus(jax.random.normal(ks[1], (2, 96, 4)))
        b = 0.5 * jax.random.normal(ks[2], (2, 96, 8))
        c = 0.5 * jax.random.normal(ks[3], (2, 96, 8))
        np.testing.assert_allclose(
            np.asarray(_ssd_chunked(xdt, log_a, b, c, 32)),
            np.asarray(reference_ssd(xdt, log_a, b, c)), atol=1e-3)


class TestMoEGating:
    @pytest.mark.parametrize("N,E,k", [(128, 16, 2), (100, 64, 6),
                                       (256, 32, 8), (64, 8, 1)])
    def test_vs_oracle(self, N, E, k):
        from repro.kernels.moe_gating.ops import fused_gating
        from repro.kernels.moe_gating.ref import reference_gating
        logits = jax.random.normal(jax.random.fold_in(KEY, N + E), (N, E))
        g1, i1 = fused_gating(logits, k, block_n=64, interpret=True)
        g2, i2 = reference_gating(logits, k)
        assert np.array_equal(np.sort(np.asarray(i1), -1),
                              np.sort(np.asarray(i2), -1))
        np.testing.assert_allclose(np.sort(np.asarray(g1), -1),
                                   np.sort(np.asarray(g2), -1), atol=1e-5)
        np.testing.assert_allclose(np.asarray(g1).sum(-1),
                                   np.ones(N), atol=1e-5)


class TestPlacementScore:
    @pytest.mark.parametrize("R,F", [(64, 4), (30, 4), (128, 2)])
    def test_vs_oracle(self, R, F):
        from repro.kernels.placement_score.kernel import placement_score
        from repro.kernels.placement_score.ref import reference_score
        ks = jax.random.split(jax.random.fold_in(KEY, R), 3)
        loads = jax.random.uniform(ks[0], (R, F)) * 2000
        caps = jnp.full((R, F), 2500.0)
        valid = (jax.random.uniform(ks[1], (R, F)) > 0.3).astype(jnp.float32)
        nf = jnp.maximum(valid.sum(-1), 1)
        row_load = jax.random.uniform(ks[2], (R,)) * 500
        row_cap = jnp.full((R,), 625.0)
        params = jnp.array([150.0, 0.75])
        f1, s1 = placement_score(loads, caps, valid, nf, row_load, row_cap,
                                 params, block_r=32, interpret=True)
        f2, s2 = reference_score(loads, caps, valid, nf, row_load, row_cap,
                                 params)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)

    def test_matches_placement_engine(self):
        """Kernel semantics agree with core.placement on a distributed hall
        (power-feasibility sub-condition + var-min score)."""
        from repro.core import hierarchy as h, placement as pl
        from repro.kernels.placement_score.ops import score_rows
        topo = h.build_topology(h.design_10n8())
        jt = pl.jax_topology(topo)
        st = pl.init_state(topo)._replace(
            lineup_ha=jnp.linspace(0, 1900, 10))
        p_dep = 300.0
        feas_k, _ = score_rows(jt.row_feeds, jt.row_nfeeds,
                               jt.row_cap[:, 0], st.lineup_ha,
                               jt.lineup_cap, st.row_load[:, 0],
                               p_dep, topo.ha_frac, interpret=True)
        dep = pl.Deployment.make(p_dep, 1, is_gpu=False)
        feas_full = pl.row_feasible(jt, st._replace(
            lineup_tot=st.lineup_ha), dep, 1)
        # engine adds HD/LD + cooling rules; kernel covers power headroom —
        # engine-feasible ⇒ kernel-feasible
        assert bool((~np.asarray(feas_full) | np.asarray(feas_k)).all())
