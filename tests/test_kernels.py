"""Pallas kernel sweeps: shapes × dtypes, assert_allclose vs pure-jnp
oracles (interpret mode on CPU; same kernels target TPU VMEM tiling)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

KEY = jax.random.PRNGKey(7)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,H,Hk,hd,causal,dt", [
        (2, 128, 4, 2, 32, True, jnp.float32),
        (1, 96, 2, 2, 16, False, jnp.float32),
        (2, 64, 4, 1, 64, True, jnp.bfloat16),
        (1, 80, 8, 4, 32, True, jnp.float32),   # non-divisible seq (pad)
    ])
    def test_vs_oracle(self, B, S, H, Hk, hd, causal, dt):
        from repro.kernels.flash_attention.ops import flash_attention
        from repro.kernels.flash_attention.ref import reference_attention
        q = jax.random.normal(KEY, (B, S, H, hd), dt)
        k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, Hk, hd), dt)
        v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, Hk, hd), dt)
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
        ref = jnp.swapaxes(reference_attention(
            jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
            jnp.swapaxes(v, 1, 2), causal=causal), 1, 2)
        tol = 0.05 if dt == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=tol)


class TestSSDScan:
    @pytest.mark.parametrize("B,S,nh,hd,st,chunk", [
        (2, 64, 4, 16, 8, 16),
        (1, 100, 8, 8, 16, 32),    # pad path
        (2, 128, 16, 32, 16, 64),
    ])
    def test_vs_naive_recurrence(self, B, S, nh, hd, st, chunk):
        from repro.kernels.ssd_scan.ops import ssd_scan
        from repro.kernels.ssd_scan.ref import reference_ssd
        ks = jax.random.split(KEY, 4)
        xdt = 0.5 * jax.random.normal(ks[0], (B, S, nh, hd), jnp.float32)
        log_a = -0.5 * jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh)))
        b = 0.5 * jax.random.normal(ks[2], (B, S, st))
        c = 0.5 * jax.random.normal(ks[3], (B, S, st))
        out = ssd_scan(xdt, log_a, b, c, chunk=chunk, head_block=4,
                       interpret=True)
        ref = reference_ssd(xdt, log_a, b, c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3)

    def test_model_chunked_matches_oracle(self):
        from repro.kernels.ssd_scan.ref import reference_ssd
        from repro.models.ssm import _ssd_chunked
        ks = jax.random.split(KEY, 4)
        xdt = 0.3 * jax.random.normal(ks[0], (2, 96, 4, 8), jnp.float32)
        log_a = -0.4 * jax.nn.softplus(jax.random.normal(ks[1], (2, 96, 4)))
        b = 0.5 * jax.random.normal(ks[2], (2, 96, 8))
        c = 0.5 * jax.random.normal(ks[3], (2, 96, 8))
        np.testing.assert_allclose(
            np.asarray(_ssd_chunked(xdt, log_a, b, c, 32)),
            np.asarray(reference_ssd(xdt, log_a, b, c)), atol=1e-3)


class TestMoEGating:
    @pytest.mark.parametrize("N,E,k", [(128, 16, 2), (100, 64, 6),
                                       (256, 32, 8), (64, 8, 1)])
    def test_vs_oracle(self, N, E, k):
        from repro.kernels.moe_gating.ops import fused_gating
        from repro.kernels.moe_gating.ref import reference_gating
        logits = jax.random.normal(jax.random.fold_in(KEY, N + E), (N, E))
        g1, i1 = fused_gating(logits, k, block_n=64, interpret=True)
        g2, i2 = reference_gating(logits, k)
        assert np.array_equal(np.sort(np.asarray(i1), -1),
                              np.sort(np.asarray(i2), -1))
        np.testing.assert_allclose(np.sort(np.asarray(g1), -1),
                                   np.sort(np.asarray(g2), -1), atol=1e-5)
        np.testing.assert_allclose(np.asarray(g1).sum(-1),
                                   np.ones(N), atol=1e-5)


def _placement_score_inputs(R, F, seed=0, p_dep=150.0, ha_frac=0.75,
                            is_ha=1.0, is_block=0.0):
    """Random [R, F] feed-gathered kernel inputs (params v2 layout)."""
    ks = jax.random.split(jax.random.fold_in(KEY, 1000 * seed + R), 4)
    loads_ha = jax.random.uniform(ks[0], (R, F)) * 1800
    loads_tot = loads_ha + jax.random.uniform(ks[3], (R, F)) * 400
    caps = jnp.full((R, F), 2500.0)
    valid = (jax.random.uniform(ks[1], (R, F)) > 0.3).astype(jnp.float32)
    nf = jnp.maximum(valid.sum(-1), 1)
    row_load = jax.random.uniform(ks[2], (R,)) * 500
    row_cap = jnp.full((R,), 625.0)
    params = jnp.array([p_dep, ha_frac, is_ha, is_block], jnp.float32)
    return loads_ha, loads_tot, caps, valid, nf, row_load, row_cap, params


class TestPlacementScore:
    @pytest.mark.parametrize("R,F", [(64, 4), (30, 4), (128, 2)])
    @pytest.mark.parametrize("is_ha,is_block",
                             [(1.0, 0.0), (0.0, 0.0), (1.0, 1.0)])
    def test_vs_oracle(self, R, F, is_ha, is_block):
        from repro.kernels.placement_score.kernel import placement_score
        from repro.kernels.placement_score.ref import reference_score
        args = _placement_score_inputs(R, F, is_ha=is_ha, is_block=is_block)
        f1, s1 = placement_score(*args, block_r=32, interpret=True)
        f2, s2 = reference_score(*args)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)

    @pytest.mark.parametrize("block_r", [8, 32, 128])
    @pytest.mark.parametrize("R", [7, 33, 127])
    def test_block_r_padding_sweep(self, block_r, R):
        """Odd row counts against every tile size: the internal padding
        (rows masked infeasible, outputs sliced back to R) must be exact
        for every remainder pattern."""
        from repro.kernels.placement_score.kernel import placement_score
        from repro.kernels.placement_score.ref import reference_score
        args = _placement_score_inputs(R, 4, seed=block_r)
        f1, s1 = placement_score(*args, block_r=block_r, interpret=True)
        assert f1.shape == s1.shape == (R,)
        f2, s2 = reference_score(*args)
        np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)

    def test_matches_placement_engine(self):
        """`score_rows` + the row/hall constraints reproduce
        `row_feasible` exactly on a distributed hall, and the public
        `use_kernel=True` dispatch is bitwise the jnp path."""
        from repro.core import hierarchy as h, placement as pl
        from repro.kernels.placement_score.ops import score_rows
        topo = h.build_topology(h.design_10n8())
        jt = pl.jax_topology(topo)
        st = pl.init_state(topo)._replace(
            lineup_ha=jnp.linspace(0, 1900, 10))
        st = st._replace(lineup_tot=st.lineup_ha)
        p_dep = 300.0
        feas_k, _ = score_rows(jt.row_feeds, jt.row_nfeeds,
                               jt.row_cap[:, 0], st.lineup_ha,
                               st.lineup_tot, jt.lineup_cap,
                               st.row_load[:, 0], p_dep, topo.ha_frac,
                               True, jt.is_block, interpret=True)
        dep = pl.Deployment.make(p_dep, 1, is_gpu=False)
        feas_full = pl.row_feasible(jt, st, dep, 1)
        # engine adds HD/LD + cooling rules; kernel covers the power
        # condition — engine-feasible ⇒ kernel-feasible
        assert bool((~np.asarray(feas_full) | np.asarray(feas_k)).all())
        feas_disp = pl.row_feasible(jt, st, dep, 1, use_kernel=True,
                                    interpret=True)
        np.testing.assert_array_equal(np.asarray(feas_full),
                                      np.asarray(feas_disp))

    def test_all_feeds_invalid(self):
        """Rows whose every `jt_row_feeds` entry is −1 (zero-capacity
        sweep-padding rows): the power condition is vacuous, the row fit
        decides, the variance score is exactly 0 — no NaN/garbage."""
        from repro.kernels.placement_score.kernel import BIG
        from repro.kernels.placement_score.ops import score_rows
        R, F, X = 16, 4, 6
        feeds = jnp.full((R, F), -1, jnp.int32)
        nfeeds = jnp.zeros((R,), jnp.int32)
        zeros_x = jnp.zeros((X,), jnp.float32)
        caps_x = jnp.full((X,), 2500.0)
        row_cap = jnp.full((R,), 625.0)
        row_load = jnp.zeros((R,), jnp.float32)
        feas, score = score_rows(feeds, nfeeds, row_cap, zeros_x, zeros_x,
                                 caps_x, row_load, 150.0, 0.75, True, False,
                                 block_r=8, interpret=True)
        assert bool(np.asarray(feas).all())
        np.testing.assert_array_equal(np.asarray(score), np.zeros((R,)))
        # and with the deployment overflowing the row: cleanly infeasible
        feas2, score2 = score_rows(feeds, nfeeds, row_cap, zeros_x, zeros_x,
                                   caps_x, row_load, 1000.0, 0.75, True,
                                   False, block_r=8, interpret=True)
        assert not bool(np.asarray(feas2).any())
        np.testing.assert_array_equal(np.asarray(score2),
                                      np.full((R,), BIG, np.float32))

    def test_rejects_float64_inputs(self):
        """x64 callers get a clear error, not silent downcast drift (the
        float32 contract in `placement_score/ops.py`)."""
        from jax.experimental import enable_x64
        from repro.kernels.placement_score.ops import score_rows
        R, F, X = 8, 2, 4
        feeds = np.zeros((R, F), np.int32)
        nfeeds = np.full((R,), F, np.int32)
        with enable_x64():
            args = [feeds, nfeeds, np.full((R,), 625.0),
                    np.zeros((X,)), np.zeros((X,)), np.full((X,), 2500.0),
                    np.zeros((R,)), 150.0, 0.75, True, False]
            with pytest.raises(TypeError, match="float64"):
                score_rows(*args, interpret=True)
