"""Scenario-generator subsystem tests (docs/scenarios.md).

Three layers: (1) trace semantics — neutral knobs reproduce the paper
baseline bit-for-bit, cohorts really decommission together, refresh
waves really snap, mix interpolation conserves total demand; (2) the
placement invariants of `tests/test_invariants.py` (conservation, load
ordering) hold on every family's traces; (3) every family runs through
`sweep()` AND `sharded_sweep()` on one shared grid with matching
results (the sharded leg exercises the real shard_map path under CI's
2 forced host devices; on one device it is the passthrough).
"""
from dataclasses import replace

import jax
import numpy as np
import pytest

from repro.core import hierarchy as h, payoff, placement as pl
from repro.core import scenarios as sc
from repro.core.arrivals import (EnvelopeSpec, Trace, generate_fleet_trace)
from repro.core.sweep import SweepAxes, sharded_sweep, sweep

SCALE = 0.005


def _base():
    return EnvelopeSpec(demand_scale=SCALE)


def _family_envs():
    """One representative perturbed envelope per family (shared grid)."""
    base = _base()
    return {
        sc.FAMILY_SHOCK: replace(base, shock_month=18,
                                 shock_multiplier=1.5),
        sc.FAMILY_COHORT: replace(base, cohort_window_m=6),
        sc.FAMILY_MIX: replace(base, mix_end=(0.8, 0.14, 0.06),
                               la_fraction=0.3),
        sc.FAMILY_REFRESH: replace(base, refresh_cycle_m=24),
    }


# ---------------------------------------------------------------- semantics


def test_neutral_knobs_reproduce_baseline_bit_for_bit():
    """Acceptance: shock multiplier 1.0 (and every other neutral knob)
    must leave the generated trace identical to the paper baseline."""
    ref = generate_fleet_trace(_base(), seed=3)
    neutral = replace(_base(), shock_month=18, shock_multiplier=1.0,
                      shock_ramp_months=6, cohort_window_m=0,
                      refresh_cycle_m=0, mix_end=None)
    got = generate_fleet_trace(neutral, seed=3)
    for f in Trace.__dataclass_fields__:
        np.testing.assert_array_equal(np.asarray(getattr(ref, f)),
                                      np.asarray(getattr(got, f)), err_msg=f)


def test_shock_scales_cumulative_demand():
    base = generate_fleet_trace(_base(), seed=3).total_kw
    surge_env = replace(_base(), shock_month=18, shock_multiplier=1.5)
    bust_env = replace(_base(), shock_month=18, shock_multiplier=0.5)
    surge = generate_fleet_trace(surge_env, seed=3).total_kw
    bust = generate_fleet_trace(bust_env, seed=3).total_kw
    assert bust < base < surge
    # demand_multiplier must track the realized totals (it drives hall
    # auto-sizing); event granularity adds a little noise
    np.testing.assert_allclose(surge / base, surge_env.demand_multiplier(),
                               rtol=0.05)
    np.testing.assert_allclose(bust / base, bust_env.demand_multiplier(),
                               rtol=0.05)


def test_ramp_shock_is_between_step_and_baseline():
    step = replace(_base(), shock_month=18, shock_multiplier=1.5)
    ramp = replace(step, shock_ramp_months=12)
    t_base = generate_fleet_trace(_base(), seed=3).total_kw
    t_step = generate_fleet_trace(step, seed=3).total_kw
    t_ramp = generate_fleet_trace(ramp, seed=3).total_kw
    assert t_base < t_ramp < t_step


def test_cohorts_decommission_together():
    """Acceptance: all same-class deployments in one cohort window share
    one decommission month."""
    W = 6
    t = generate_fleet_trace(replace(_base(), cohort_window_m=W), seed=0)
    decom = np.asarray(t.month) + np.asarray(t.lifetime_m)
    cohort = np.asarray(t.month) // W
    n_cohorts = 0
    for cid in np.unique(t.class_id):
        in_class = np.asarray(t.class_id) == cid
        for c in np.unique(cohort[in_class]):
            sel = in_class & (cohort == c)
            assert len(np.unique(decom[sel])) == 1, (cid, c)
            n_cohorts += 1
    assert n_cohorts > 3, "trace too small to exercise cohorts"
    # the un-correlated trace has scattered decommission months
    t0 = generate_fleet_trace(_base(), seed=0)
    assert len(np.unique(np.asarray(t0.month) + np.asarray(t0.lifetime_m))) \
        > len(np.unique(decom))


def test_cohorts_stay_shared_for_windows_wider_than_lifetimes():
    """Windows wider than the ~5–7 yr lifetime draws must still put every
    cohort member on one shared epoch (the epoch floors at window end)."""
    W = 96
    t = generate_fleet_trace(replace(_base(), cohort_window_m=W), seed=0)
    decom = np.asarray(t.month) + np.asarray(t.lifetime_m)
    cohort = np.asarray(t.month) // W
    for cid in np.unique(t.class_id):
        in_class = np.asarray(t.class_id) == cid
        for c in np.unique(cohort[in_class]):
            sel = in_class & (cohort == c)
            assert len(np.unique(decom[sel])) == 1, (cid, c)
    assert np.all(np.asarray(t.lifetime_m) >= 1)


def test_refresh_waves_snap_to_cycle():
    C = 24
    t = generate_fleet_trace(replace(_base(), refresh_cycle_m=C), seed=0)
    decom = np.asarray(t.month) + np.asarray(t.lifetime_m)
    assert np.all(decom % C == 0)
    assert np.all(np.asarray(t.lifetime_m) >= 1)
    # arrivals are untouched: same months/power as the baseline trace
    t0 = generate_fleet_trace(_base(), seed=0)
    np.testing.assert_array_equal(t.month, t0.month)
    np.testing.assert_allclose(t.total_kw, t0.total_kw)


def test_mix_interpolation_conserves_total_demand():
    env = replace(_base(), mix_end=(0.8, 0.14, 0.06))
    tot_base = sum(_base().annual_targets_kw(c) for c in (0, 1, 2))
    tot_mix = sum(env.annual_targets_kw(c) for c in (0, 1, 2))
    np.testing.assert_allclose(tot_base, tot_mix, rtol=1e-9)
    # end-year split hits the target share; start year keeps the baseline
    np.testing.assert_allclose(env.annual_targets_kw(0)[-1] / tot_mix[-1],
                               0.8, atol=1e-9)
    np.testing.assert_allclose(env.annual_targets_kw(0)[0],
                               _base().annual_targets_kw(0)[0], rtol=1e-9)
    # degenerate one-year horizon: the only year IS end_year, so the
    # target split applies outright instead of silently no-opping
    one = replace(env, start_year=2028, end_year=2028)
    tot1 = sum(one.annual_targets_kw(c) for c in (0, 1, 2))
    np.testing.assert_allclose(one.annual_targets_kw(0) / tot1, 0.8,
                               atol=1e-9)


def test_batch_labels_and_tags():
    base = _base()
    for batch in sc.all_families(base).values():
        assert batch.family in sc.FAMILIES
        assert len(batch.labels) == len(batch.envs) == len(batch)
        assert all(t.startswith(batch.family + ":") for t in batch.tags())
    axes = sc.demand_shocks(base, months=(12,), multipliers=(1.25,),
                            ramp_months=(0,)).axes(
        [h.get_design("4N/3"), h.get_design("3+1")], seeds=(0, 1))
    assert len(axes) == 4                       # 2 designs × 1 env × 2 seeds
    assert set(axes.tags) == {"shock:m12_x1.25_step"}
    with pytest.raises(ValueError):
        sc.ScenarioBatch("shock", ("a",), (base, base))


# --------------------------------------------------------------- invariants


_PLACE = jax.jit(pl.place)


@pytest.mark.parametrize("family", sc.FAMILIES)
def test_scenario_traces_satisfy_placement_invariants(family):
    """Place the head of each family's trace, then release 100%: loads
    must return to the initial state, and the line-up ordering
    `lineup_tot >= lineup_ha >= 0` must hold after every step."""
    trace = generate_fleet_trace(_family_envs()[family], seed=11)
    topo = h.build_topology(h.get_design("3+1"))
    jt = pl.jax_topology(topo)
    st0 = pl.init_state(topo)
    key = jax.random.PRNGKey(0)

    n = min(len(trace), 24)
    state, rows, counts, placed = st0, [], [], []
    for i in range(n):
        dep = pl.Deployment.make(
            float(trace.rack_kw[i]), int(trace.n_racks[i]),
            is_gpu=bool(trace.is_gpu[i]), tier=int(trace.tier[i]),
            is_pod=bool(trace.is_pod[i]))
        state, ok, r, c = _PLACE(jt, state, dep, pl.POLICY_VAR_MIN,
                                 jax.random.fold_in(key, i))
        rows.append(r)
        counts.append(c)
        placed.append(bool(ok))
        ha = np.asarray(state.lineup_ha)
        tot = np.asarray(state.lineup_tot)
        assert (ha >= -1e-3).all()
        assert (tot >= ha - 1e-3).all()
    placed = np.asarray(placed)
    assert placed.any(), f"{family} trace placed nothing; test is vacuous"

    state = pl.release_bulk(jt, state, np.stack(rows), np.stack(counts),
                            np.asarray(trace.rack_kw[:n]),
                            np.asarray(trace.is_gpu[:n]),
                            np.asarray(trace.tier[:n]),
                            np.asarray(placed, np.float32))
    np.testing.assert_allclose(np.asarray(state.row_load),
                               np.asarray(st0.row_load), atol=0.5)
    np.testing.assert_allclose(np.asarray(state.lineup_ha),
                               np.asarray(st0.lineup_ha), atol=0.05)
    np.testing.assert_allclose(np.asarray(state.lineup_tot),
                               np.asarray(st0.lineup_tot), atol=0.05)
    np.testing.assert_allclose(np.asarray(state.hall_liq),
                               np.asarray(st0.hall_liq), atol=0.05)


# -------------------------------------------------- sweep + sharded_sweep


@pytest.fixture(scope="module")
def shared_grid():
    """Baseline + one envelope per family on one tagged grid."""
    envs = [_base()] + list(_family_envs().values())
    tags = [sc.BASELINE_TAG] + [f + ":rep" for f in sc.FAMILIES]
    return SweepAxes.product(designs=[h.get_design("3+1")], envs=envs,
                             seeds=(0,), env_tags=tags)


def test_all_families_through_sweep_and_sharded_sweep(shared_grid):
    """Acceptance: all four families run through `sweep()` AND
    `sharded_sweep()` on a shared grid with matching results (real
    shard_map path under CI's 2 forced host devices; passthrough on 1)."""
    res_1 = sweep(shared_grid)
    res_d = sharded_sweep(shared_grid)
    assert len(res_1) == len(res_d) == 5
    assert res_1.tags == res_d.tags
    assert {t.split(":", 1)[0] for t in res_1.tags} \
        == set(sc.FAMILIES) | {"baseline"}
    np.testing.assert_array_equal(res_1.n_halls_built, res_d.n_halls_built)
    np.testing.assert_allclose(res_1.final_deployed_mw,
                               res_d.final_deployed_mw, rtol=1e-6)
    np.testing.assert_allclose(res_1.p90_stranding, res_d.p90_stranding,
                               atol=1e-6)
    np.testing.assert_allclose(res_1.placed_fraction, res_d.placed_fraction,
                               atol=1e-7)
    # surge scenarios must still place everything: hall auto-sizing
    # accounts for the shock multiplier
    np.testing.assert_allclose(res_1.placed_fraction,
                               np.ones(len(res_1)), atol=1e-6)


def test_frontier_reports_deltas_against_baseline():
    families = {f: sc.ScenarioBatch(f, ("rep",), (env,))
                for f, env in _family_envs().items()}
    pts = payoff.scenario_frontier(h.get_design("3+1"), base_env=_base(),
                                   families=families)
    assert {p.family for p in pts} == set(sc.FAMILIES) | {"baseline"}
    by_family = {p.family: p for p in pts}
    bl = by_family["baseline"]
    assert bl.d_p90 == bl.d_capex == bl.d_dpm == 0.0
    for p in pts:
        assert 0.0 <= p.p90_stranding <= 1.0
        assert p.p50_stranding <= p.p90_stranding + 1e-6
        np.testing.assert_allclose(
            p.d_p90, p.p90_stranding - bl.p90_stranding, atol=1e-6)
