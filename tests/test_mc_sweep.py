"""Batched single-hall MC engine + split-trace fleet scan (ISSUE 4).

Three equivalence obligations:

* `mc_sweep` over a configuration grid must reproduce the sequential
  one-configuration `singlehall.monte_carlo` wrapper per config — the
  same `sample_mixed_traces` batch is generated either way, and topology
  padding is inert, so results are bitwise-equal up to float tolerance.
* the fleet engine's split-trace pod scan must reproduce the
  pre-refactor `lax.cond(is_pod, …)`+retry path exactly
  (`legacy_pod_cond=True` keeps that path compilable as the reference)
  and the pre-refactor golden pod-grid numbers.
* `sharded_mc_sweep` over ≥2 devices must match single-device `mc_sweep`.

Multi-device cases force simulated host devices BEFORE jax initializes
(the test_sharded_sweep.py pattern); in-suite they rely on CI exporting
``XLA_FLAGS=--xla_force_host_platform_device_count=2``.
"""
import os
import sys

if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=2").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.core import arrivals, hierarchy as h  # noqa: E402
from repro.core import placement as pl, projections as proj  # noqa: E402
from repro.core.arrivals import (EnvelopeSpec,  # noqa: E402
                                 generate_fleet_trace, sample_mixed_traces)
from repro.core.fleet import FleetConfig, run_fleet  # noqa: E402
from repro.core.mc_sweep import (MCAxes, mc_sweep,  # noqa: E402
                                 sharded_mc_sweep)
from repro.core.singlehall import monte_carlo  # noqa: E402
from repro.core.sweep import SweepAxes, sweep  # noqa: E402

needs_devices = pytest.mark.skipif(
    jax.device_count() < 2, reason="needs >=2 host devices")

MC_KW = dict(n_trials=4, n_events=150, year=2030, scenario=proj.HIGH)


def _assert_mc_equal(batch_res, wrapper_res):
    for key in ("saturated", "placed_a", "placed_b"):
        np.testing.assert_array_equal(batch_res[key], wrapper_res[key],
                                      err_msg=key)
    for key in ("lineup_stranding", "hall_stranding", "deployed_kw"):
        np.testing.assert_allclose(batch_res[key], wrapper_res[key],
                                   rtol=1e-6, atol=1e-5, err_msg=key)
    assert batch_res["ha_capacity_kw"] == wrapper_res["ha_capacity_kw"]


# ---------------------------------------------------------------------------
# mc_sweep ≡ sequential monte_carlo
# ---------------------------------------------------------------------------

def test_mc_sweep_matches_sequential():
    """Heterogeneous (design, policy, seed) batch: every configuration
    must match its sequential `monte_carlo` call — identical trace batch,
    inert topology padding (10N/8 forces padding on the small halls)."""
    axes = MCAxes.zip(
        designs=[h.get_design(n) for n in ("4N/3", "3+1", "10N/8")],
        policies=[pl.POLICY_VAR_MIN, pl.POLICY_MIN_WASTE,
                  pl.POLICY_VAR_MIN],
        seeds=[11, 11, 13])
    res = mc_sweep(axes, **MC_KW)
    assert len(res) == 3 and res.n_trials == MC_KW["n_trials"]
    for i in range(len(axes)):
        w = monte_carlo(axes.designs[i], policy=axes.policies[i],
                        seed=axes.seeds[i], **MC_KW)
        _assert_mc_equal(res.result(i), w)
        # padding stripped: per-config line-up axis is the design's own
        assert res.result(i)["lineup_stranding"].shape == \
            (MC_KW["n_trials"], axes.designs[i].n_lineups)


def test_mc_sweep_fig6_single_sku_mode():
    """`single_sku_gpu` + per-config `sku_kw` as generator arguments:
    batched grid ≡ sequential wrapper, and every event is a GPU rack at
    the override power."""
    axes = MCAxes.product(designs=[h.get_design("4N/3"),
                                   h.get_design("3+1")],
                          sku_kw=(400.0, 900.0), seeds=(6,))
    res = mc_sweep(axes, n_trials=3, n_events=120, harvest=False,
                   single_sku_gpu=True)
    for i in range(len(axes)):
        w = monte_carlo(axes.designs[i], n_trials=3, n_events=120,
                        harvest=False, single_sku_gpu=True,
                        sku_kw_override=axes.sku_kw[i], seed=6)
        _assert_mc_equal(res.result(i), w)

    t = sample_mixed_traces(3, 120, seed=6, sku_kw_override=700.0,
                            single_sku_gpu=True)
    assert (t.is_gpu.all() and (t.rack_kw == 700.0).all()
            and (t.class_id == 0).all())


def test_sample_mixed_traces_semantics():
    """One vectorized pass: reproducible per (args, seed), distinct across
    seeds, and mix parameters land in the right columns."""
    a = sample_mixed_traces(4, 200, seed=3)
    b = sample_mixed_traces(4, 200, seed=3)
    c = sample_mixed_traces(4, 200, seed=4)
    for f in ("class_id", "rack_kw", "lifetime_m", "tier"):
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f))
    assert not np.array_equal(a.rack_kw, c.rack_kw)
    assert a.rack_kw.shape == (4, 200) and len(a) == 4
    assert a.trial(2).rack_kw.shape == (200,)

    t = sample_mixed_traces(2, 300, seed=5, pod_racks=5, la_fraction=1.0)
    gpu = t.is_gpu
    assert (t.is_pod == gpu).all() and (t.n_racks[gpu] == 5).all()
    assert (t.tier == 1).all()          # TIER_LA everywhere
    assert (t.lifetime_m >= 12).all()
    # realized GPU power share tracks the requested 0.6 calibration
    p = t.rack_kw.astype(float) * t.n_racks
    share = p[gpu].sum() / p.sum()
    assert 0.4 < share < 0.8


def test_monte_carlo_topology_cache():
    """Repeated wrapper calls stage each (design, padding) topology once."""
    from repro.core.mc_sweep import _TOPO_CACHE, _staged_topology
    d = h.get_design("4N/3")
    key = (d, d.n_rows, d.n_lineups)
    _TOPO_CACHE.pop(key, None)
    e1 = _staged_topology(d, d.n_rows, d.n_lineups)
    e2 = _staged_topology(d, d.n_rows, d.n_lineups)
    assert e1 is e2 and key in _TOPO_CACHE


def test_mc_axes_product_tags():
    """`product` carries `tags` (aligned with designs, like
    `SweepAxes.product(env_tags=…)`) instead of dropping them."""
    axes = MCAxes.product(designs=[h.get_design("4N/3"),
                                   h.get_design("3+1")],
                          sku_kw=(400.0, 900.0), seeds=(1, 2),
                          tags=("dist", "block"))
    assert axes.tags == ["dist"] * 4 + ["block"] * 4
    assert MCAxes.product(designs=[h.get_design("4N/3")],
                          seeds=(1, 2)).tags == ["", ""]
    with pytest.raises(ValueError):
        MCAxes.product(designs=[h.get_design("4N/3")], tags=("a", "b"))


# ---------------------------------------------------------------------------
# single-hall split-pods fast path ≡ legacy per-event cond
# ---------------------------------------------------------------------------

def test_sample_mixed_traces_pods_first():
    """With `pod_racks > 1` every trial's pod events precede its cluster
    events (stable reorder: in-group order, marginals and the realized
    power mix are untouched) and the batch exposes its window geometry."""
    t = sample_mixed_traces(4, 200, seed=9, pod_racks=5)
    ip = t.is_pod
    assert not np.any(ip[:, 1:] & ~ip[:, :-1])      # never False → True
    np.testing.assert_array_equal(t.n_pods, ip.sum(axis=1))
    assert t.max_pod_racks == 5
    assert (t.n_racks[ip] == 5).all() and (t.is_gpu == ip).all()
    # pod_racks=1 skips the reorder entirely and reports the sentinel
    # pod size (the pod-free placement mode's contract)
    assert sample_mixed_traces(4, 200, seed=9, pod_racks=1).max_pod_racks == 1

    from repro.core.mc_sweep import _pod_geometry
    wa, sa = _pod_geometry([t])
    assert wa == int(t.n_pods.max()) and sa == int(t.n_pods.min())
    bad = sample_mixed_traces(2, 50, seed=9, pod_racks=3)
    bad.is_pod = np.zeros_like(bad.is_pod)
    bad.is_pod[:, 1] = True                          # a cluster before a pod
    with pytest.raises(ValueError, match="precede"):
        _pod_geometry([bad])


@pytest.mark.parametrize("pod_racks", [3, 7])
def test_mc_split_pods_matches_legacy_cond(pod_racks):
    """The split-pods fast path (pods-first windows, trimmed rack scan,
    HD-compacted row view) must be bit-identical to the legacy per-event
    `lax.cond(is_pod, …)` path on the same traces."""
    axes = MCAxes.zip(designs=[h.get_design("10N/8"), h.get_design("3+1")],
                      seeds=[11, 12])
    kw = dict(n_trials=2, n_events=100, year=2030, scenario=proj.HIGH,
              pod_racks=pod_racks)
    res_split = mc_sweep(axes, **kw)
    res_legacy = mc_sweep(axes, legacy_pod_cond=True, **kw)
    for f in ("lineup_stranding", "hall_stranding", "deployed_kw",
              "saturated", "placed_a", "placed_b"):
        np.testing.assert_array_equal(getattr(res_split, f),
                                      getattr(res_legacy, f), err_msg=f)


def test_refill_stream_decorrelated_from_adjacent_seed():
    """Refill traces draw from the phase-1 stream of the same seed; the
    old `seed + 1` refill was bitwise the next configuration's fill
    trace, correlating trials across adjacent-seed grid points."""
    refill = sample_mixed_traces(3, 120, seed=7, phase=1)
    next_fill = sample_mixed_traces(3, 120, seed=8)
    own_fill = sample_mixed_traces(3, 120, seed=7)
    assert not np.array_equal(refill.rack_kw, next_fill.rack_kw)
    assert not np.array_equal(refill.rack_kw, own_fill.rack_kw)
    # still deterministic per (seed, phase)
    np.testing.assert_array_equal(
        refill.rack_kw, sample_mixed_traces(3, 120, seed=7, phase=1).rack_kw)


# ---------------------------------------------------------------------------
# split-trace fleet scan ≡ pre-refactor pod path
# ---------------------------------------------------------------------------

def _pod_env(pod, scale=0.01):
    return EnvelopeSpec(demand_scale=scale, gpu_scenario=proj.HIGH,
                        pod_racks=pod, pod_scale_arch=True)


def test_split_trace_matches_legacy_pod_cond():
    """The split-trace scan and the pre-refactor `lax.cond` path must be
    exactly equivalent on a shared-trace pod grid (same RNG keys via the
    per-month pod-count offset)."""
    axes = SweepAxes.zip(
        designs=[h.get_design("10N/8"), h.get_design("8+2")],
        envs=[_pod_env(3, 0.005), _pod_env(5, 0.005)],
        seeds=[3, 4])
    traces = [generate_fleet_trace(e, s)
              for e, s in zip(axes.envs, axes.seeds)]
    res_split = sweep(axes, traces=traces)
    res_legacy = sweep(axes, traces=traces, legacy_pod_cond=True)
    np.testing.assert_array_equal(res_split.n_halls_built,
                                  res_legacy.n_halls_built)
    for f in ("final_deployed_mw", "placed_fraction", "p50_stranding",
              "p90_stranding", "halls_active", "final_lineup_stranding"):
        np.testing.assert_allclose(getattr(res_split, f),
                                   getattr(res_legacy, f), atol=1e-6,
                                   err_msg=f)


def test_pod_golden_regression():
    """Fixed-seed pod-grid numbers captured from the PRE-refactor
    `lax.cond` engine (100 MW, High TDP): the split-trace scan must
    reproduce them — guards ordering, RNG alignment, and the
    `pod_scan_len` trim against silent drift."""
    golden = {
        ("10N/8", 5, 3): (8, 60.0096, 0.990950, 0.6386),
        ("3+1", 5, 9): (11, 35.8188, 0.978448, 0.6239),
    }
    for (dname, pod, seed), (halls, dep, pf, p90) in golden.items():
        r = run_fleet(FleetConfig(h.get_design(dname), _pod_env(pod),
                                  seed=seed))
        assert r.n_halls_built == halls, (dname, r.n_halls_built)
        np.testing.assert_allclose(r.final_deployed_mw, dep, atol=0.01)
        np.testing.assert_allclose(r.placed_fraction, pf, atol=1e-4)
        np.testing.assert_allclose(float(r.p90_stranding[-1]), p90,
                                   atol=2e-3)


# ---------------------------------------------------------------------------
# sharded mc_sweep (2 forced host devices)
# ---------------------------------------------------------------------------

@needs_devices
def test_sharded_mc_sweep_matches_single_device():
    axes = MCAxes.zip(designs=[h.get_design("4N/3"), h.get_design("3+1")],
                      seeds=[21, 22])
    res_1 = mc_sweep(axes, **MC_KW)
    res_d = sharded_mc_sweep(axes, **MC_KW)
    np.testing.assert_array_equal(res_1.saturated, res_d.saturated)
    for f in ("lineup_stranding", "hall_stranding", "deployed_kw"):
        np.testing.assert_allclose(getattr(res_1, f), getattr(res_d, f),
                                   rtol=1e-6, atol=1e-5, err_msg=f)


@needs_devices
def test_sharded_mc_sweep_remainder_grid():
    """3 configurations over 2 devices: pad-with-config-0 then drop."""
    axes = MCAxes.zip(designs=[h.get_design("4N/3")], seeds=[31, 32, 33])
    res_1 = mc_sweep(axes, n_trials=3, n_events=100)
    res_d = sharded_mc_sweep(axes, n_trials=3, n_events=100)
    assert len(res_d) == 3
    np.testing.assert_allclose(res_1.deployed_kw, res_d.deployed_kw,
                               rtol=1e-6, atol=1e-5)


def test_sharded_mc_sweep_passthrough_single_device():
    axes = MCAxes.zip(designs=[h.get_design("4N/3")], seeds=[41])
    res_d = sharded_mc_sweep(axes, n_trials=2, n_events=80,
                             devices=jax.devices()[:1])
    res_1 = mc_sweep(axes, n_trials=2, n_events=80)
    np.testing.assert_array_equal(res_1.deployed_kw, res_d.deployed_kw)
